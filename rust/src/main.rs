//! CarbonEdge CLI — the L3 leader entrypoint.
//!
//! ```text
//! carbonedge info                                   # platform + manifest summary
//! carbonedge golden [--model NAME]                  # end-to-end numerics gate
//! carbonedge serve --model NAME --mode green ...    # serve a workload, print report
//! carbonedge reproduce [--table 2|3|4|5] [--fig 2|3] [--all]
//! carbonedge sweep [--step 0.05] [--iters 20]       # Fig. 3 weight sweep
//! carbonedge overhead                               # scheduling overhead micro-report
//! carbonedge sim --scenario <name|list> [--nodes N] [--requests M]
//!               [--seed S] [--mode green [--json]] [--scheduler defer-green]
//!               [--sweep [--step 0.1]]
//!               [--idle-w W] [--slack S [--headroom S] [--defer-resolution S]
//!               [--defer-min-gain F]] [--no-defer] [--compare-defer]
//!               [--compare-defer-routing] [--trace-csv PATH]
//!               [--trace-out PATH [--trace-filter KINDS]] [--timeline-stride N]
//!               [--consolidate LARGE] [--list-scenarios]
//!               [--pv-peak-w W | --pv-csv PATH] [--battery-wh WH]
//!               [--battery-rt-eff F] [--compare-microgrid]
//!               [--charge-policy off|threshold] [--charge-threshold-pct P]
//!               [--compare-arbitrage]
//!               [--batch-window-ms MS] [--batch-max N] [--compare-batching]
//!               [--sites N] [--router nearest|carbon|deadline]
//!               [--compare-routers]
//!               [--monitor SPEC] [--telemetry-out PATH]
//!               [--help]
//!                                                   # virtual-time fleet simulator
//! carbonedge replay TRACE.ndjson [--verify] [--json] # reconstruct a report from a trace
//! carbonedge replay --diff A.ndjson B.ndjson         # first divergent event between traces
//! ```

use anyhow::Result;

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::experiments as exp;
use carbonedge::metrics::RunReport;
use carbonedge::scheduler::{Amp4ecScheduler, CarbonAwareScheduler, Mode, Scheduler};
use carbonedge::util::cli::Args;
use carbonedge::workload::{Arrivals, RequestStream};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn config_from(args: &Args) -> Result<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    cfg.iterations = args.parse_or("iters", cfg.iterations)?;
    cfg.repetitions = args.parse_or("reps", cfg.repetitions)?;
    Ok(cfg)
}

fn run() -> Result<()> {
    let args = Args::from_env(&[
        "all",
        "verbose",
        "sweep",
        "json",
        "help",
        "no-defer",
        "compare-defer",
        "compare-defer-routing",
        "list-scenarios",
        "compare-microgrid",
        "compare-arbitrage",
        "compare-batching",
        "compare-routers",
        "diff",
        "verify",
        "deny",
    ])?;
    let cmd = args.command.clone().unwrap_or_else(|| "info".to_string());
    // Handle --help before any command arm so no command ever runs its
    // workload when the user only asked for usage text.
    if args.bool_flag("help") {
        if cmd == "sim" {
            print_sim_help();
        } else {
            print_usage();
        }
        return Ok(());
    }
    let cfg = config_from(&args)?;

    match cmd.as_str() {
        "info" => {
            let coord = Coordinator::new(cfg)?;
            println!("CarbonEdge — carbon-aware edge inference");
            println!("artifacts: {}", coord.cfg.artifacts_dir);
            println!("image size: {}x{}", coord.manifest.image_size, coord.manifest.image_size);
            for (name, m) in &coord.manifest.models {
                println!(
                    "  model {name}: {:.2}M params, {:.1}M flops, {} stages",
                    m.params as f64 / 1e6,
                    m.flops as f64 / 1e6,
                    m.stages.len()
                );
            }
            println!("nodes:");
            for n in &coord.cfg.nodes {
                println!(
                    "  {}: {} cpu, {} MB, {} gCO2/kWh",
                    n.name, n.cpu_quota, n.mem_mb, n.intensity
                );
            }
        }
        "golden" => {
            let coord = Coordinator::new(cfg)?;
            let names: Vec<String> = match args.get("model") {
                Some(m) => vec![m.to_string()],
                None => coord.manifest.models.keys().cloned().collect(),
            };
            for name in names {
                let model = coord.load_model(&name)?;
                let err = coord.golden_check(&model)?;
                println!("golden {name}: OK (max |Δlogit| = {err:.2e})");
            }
        }
        "serve" => {
            let model_name = args.str_or("model", "mobilenet_v2");
            let mode = Mode::parse(&args.str_or("mode", "green"))
                .ok_or_else(|| anyhow::anyhow!("bad --mode"))?;
            let count = args.parse_or("requests", 50usize)?;
            let rate = args.parse_or("rate", 0.0f64)?;
            let coord = Coordinator::new(cfg)?;
            let model = coord.load_model(&model_name)?;
            let registry = coord.calibrated_registry(&model)?;
            let containers = carbonedge::deployer::deploy_task_level(
                &coord.exec(),
                &model,
                registry.nodes(),
                &coord.cfg,
            )?;
            let arrivals = if rate > 0.0 {
                Arrivals::Poisson { count, rate_hz: rate, seed: 42 }
            } else {
                Arrivals::ClosedLoop { count }
            };
            let stream =
                RequestStream { image_size: coord.manifest.image_size, arrivals, seed: 0 };
            let mut sched = CarbonAwareScheduler::new(mode.name(), mode.weights());
            let loop_ = carbonedge::coordinator::ServingLoop::new(&registry, &containers);
            let out = loop_.serve(&stream, &mut sched, &format!("serve-{}", mode.name()))?;
            print_report(&out.report);
            println!("queue wait: {:.3} ms mean", out.queue_ms_mean);
            println!("scheduling: {:.4} ms mean", out.sched_ms_mean);
        }
        "reproduce" => {
            let coord = Coordinator::new(cfg)?;
            let all = args.bool_flag("all") || (!args.has("table") && !args.has("fig"));
            let iters = coord.cfg.iterations;
            let reps = coord.cfg.repetitions;
            let model = args.str_or("model", "mobilenet_v2");
            let mut t2_cache: Option<exp::Table2> = None;
            let want_table = |n: &str| all || args.get_all("table").contains(&n);
            let want_fig = |n: &str| all || args.get_all("fig").contains(&n);

            if want_table("2") || want_fig("2") || want_table("3") {
                let t2 = exp::table2(&coord, &model, iters, reps)?;
                if want_table("2") {
                    println!("{}", t2.render());
                }
                if want_fig("2") {
                    println!("{}", exp::fig2_render(&t2));
                }
                if want_table("3") {
                    println!("{}", exp::table3_render(t2.green_reduction()));
                }
                t2_cache = Some(t2);
            }
            if want_table("4") {
                let models: Vec<String> = coord.manifest.models.keys().cloned().collect();
                let refs: Vec<&str> = models.iter().map(String::as_str).collect();
                let rows = exp::table4(&coord, &refs, iters, reps)?;
                println!("{}", exp::table4_render(&rows));
            }
            if want_table("5") {
                let t5 = exp::table5(&coord, &model, iters)?;
                println!("{}", exp::table5_render(&t5));
            }
            if want_fig("3") {
                let step = args.parse_or("step", 0.05f64)?;
                let mono = match &t2_cache {
                    Some(t2) => t2.reports[0].clone(),
                    None => exp::run_strategy(&coord, &model, exp::Strategy::Monolithic, iters, 1)?,
                };
                let points = exp::fig3_sweep(&coord, &model, iters, step)?;
                println!("{}", exp::fig3_render(&points, &mono));
            }
            if all {
                let s = exp::scheduling_overhead(&coord, &model, iters)?;
                println!(
                    "Scheduling overhead: {:.4} ms mean / {:.4} ms p95 per task",
                    s.mean, s.p95
                );
            }
        }
        "sweep" => {
            let coord = Coordinator::new(cfg)?;
            let step = args.parse_or("step", 0.05f64)?;
            let model = args.str_or("model", "mobilenet_v2");
            let iters = coord.cfg.iterations;
            let mono = exp::run_strategy(&coord, &model, exp::Strategy::Monolithic, iters, 1)?;
            let points = exp::fig3_sweep(&coord, &model, iters, step)?;
            println!("{}", exp::fig3_render(&points, &mono));
        }
        "overhead" => {
            let coord = Coordinator::new(cfg)?;
            let model = args.str_or("model", "mobilenet_v2");
            let s = exp::scheduling_overhead(&coord, &model, coord.cfg.iterations)?;
            println!(
                "scheduling overhead: mean {:.4} ms, p50 {:.4} ms, p95 {:.4} ms (n={})",
                s.mean, s.p50, s.p95, s.n
            );
        }
        "baselines" => {
            // extra: compare all schedulers (ablation)
            let coord = Coordinator::new(cfg)?;
            let model_name = args.str_or("model", "mobilenet_v2");
            let model = coord.load_model(&model_name)?;
            let stream = RequestStream::paper_default(coord.manifest.image_size);
            let mut scheds: Vec<Box<dyn Scheduler>> = vec![
                Box::new(Amp4ecScheduler::new()),
                Box::new(CarbonAwareScheduler::new("green", Mode::Green.weights())),
                Box::new(carbonedge::scheduler::RoundRobinScheduler::new()),
                Box::new(carbonedge::scheduler::RandomScheduler::new(7)),
                Box::new(carbonedge::scheduler::LeastLoadedScheduler),
            ];
            for s in scheds.iter_mut() {
                let run = coord.run_scheduled(&model, s.as_mut(), &stream.inputs())?;
                let r = RunReport::from_records(s.name(), &run.records)?;
                print_report(&r);
            }
        }
        "sim" => {
            // Pure virtual time — no artifacts, no Coordinator.
            let name = args.str_or("scenario", "paper-3-node");
            if args.bool_flag("list-scenarios") || name == "list" {
                println!("scenarios:");
                for n in carbonedge::sim::SCENARIO_NAMES {
                    println!("  {n}");
                }
                return Ok(());
            }
            let nodes = args.parse_or("nodes", 0usize)?;
            let requests = args.parse_or("requests", 0usize)?;
            let seed = args.parse_or("seed", 42u64)?;
            // Observability knobs: an NDJSON event firehose plus report-
            // export downsampling. Parsed up front so every later arm can
            // reject combinations loudly.
            let trace_out = args.get("trace-out").map(str::to_string);
            if args.has("trace-filter") && trace_out.is_none() {
                anyhow::bail!("--trace-filter needs --trace-out");
            }
            let trace_filter = match args.get("trace-filter") {
                Some(spec) => carbonedge::obs::TraceFilter::parse(spec)
                    .map_err(|e| anyhow::anyhow!("--trace-filter: {e}"))?,
                None => carbonedge::obs::TraceFilter::all(),
            };
            // In-sim monitors and the telemetry export ride the same
            // single-run instrumentation path as the firehose; without
            // --trace-out they run against a NullSink (counters only).
            let telemetry_out = args.get("telemetry-out").map(str::to_string);
            let monitors = match args.get("monitor") {
                Some(spec) => Some(
                    carbonedge::obs::MonitorSet::parse(spec)
                        .map_err(|e| anyhow::anyhow!("--monitor: {e}"))?,
                ),
                None => None,
            };
            let timeline_stride = args.parse_or("timeline-stride", 1usize)?;
            if args.has("timeline-stride") && !args.bool_flag("json") {
                anyhow::bail!("--timeline-stride only applies to --json report output");
            }
            // Validate here so bad CLI input gets a clean error, not a
            // library assert panic.
            if name == "churn" && nodes > 0 && nodes < 3 {
                anyhow::bail!("the churn scenario needs --nodes >= 3 (survivors must exist)");
            }
            if let Some(large) = args.get("consolidate") {
                // Idle-floor A/B: same workload on a small vs large fleet.
                // It builds its own pair of consolidation scenarios, so any
                // other sim knob would be silently ignored — reject loudly
                // instead.
                for flag in [
                    "trace-csv",
                    "trace-out",
                    "trace-filter",
                    "timeline-stride",
                    "monitor",
                    "telemetry-out",
                    "idle-w",
                    "slack",
                    "headroom",
                    "defer-resolution",
                    "defer-min-gain",
                    "mode",
                    "scheduler",
                    "step",
                    "pv-peak-w",
                    "pv-csv",
                    "battery-wh",
                    "battery-rt-eff",
                    "charge-policy",
                    "charge-threshold-pct",
                    "batch-window-ms",
                    "batch-max",
                    "sites",
                    "router",
                ] {
                    if args.has(flag) {
                        anyhow::bail!("--consolidate does not combine with --{flag}");
                    }
                }
                for switch in [
                    "sweep",
                    "json",
                    "no-defer",
                    "compare-defer",
                    "compare-defer-routing",
                    "compare-microgrid",
                    "compare-arbitrage",
                    "compare-batching",
                    "compare-routers",
                ] {
                    if args.bool_flag(switch) {
                        anyhow::bail!("--consolidate does not combine with --{switch}");
                    }
                }
                if args.has("scenario") && name != "consolidation" {
                    anyhow::bail!("--consolidate always runs the consolidation scenario");
                }
                let large: usize = large
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--consolidate expects a fleet size"))?;
                let small = if nodes == 0 { 3 } else { nodes };
                if large <= small {
                    anyhow::bail!("--consolidate {large} must exceed the small fleet ({small})");
                }
                let (s, l) = exp::sim_consolidation(small, large, requests, seed);
                println!("{}", exp::sim_consolidation_render(&s, &l));
                return Ok(());
            }
            let mut sc = if let Some(path) = args.get("trace-csv") {
                if name != "real-trace" {
                    anyhow::bail!("--trace-csv only applies to --scenario real-trace");
                }
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
                carbonedge::sim::scenarios::real_trace_from_csv(&text, nodes, requests, seed)
                    .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?
            } else {
                carbonedge::sim::scenarios::build(&name, nodes, requests, seed).ok_or_else(
                    || match carbonedge::sim::scenarios::suggest(&name) {
                        Some(close) => anyhow::anyhow!(
                            "unknown scenario {name:?}; did you mean {close:?}? \
                             (--list-scenarios prints all)"
                        ),
                        None => anyhow::anyhow!(
                            "unknown scenario {name:?}; --list-scenarios prints all of {:?}",
                            carbonedge::sim::SCENARIO_NAMES
                        ),
                    },
                )?
            };
            // Geographic knobs: --sites rebuilds the region roster
            // (timezones spread uniformly over the day), --router swaps
            // the cross-site policy. Both need a site layer to act on.
            if let Some(k) = args.get("sites") {
                let k: usize = k
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--sites expects a site count, got {k:?}"))?;
                sc = carbonedge::sim::scenarios::with_site_count(&name, k, nodes, requests, seed)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "--sites needs >= 2 sites and a geographic scenario \
                             (multi-site, follow-the-sun), got {k} over {name:?}"
                        )
                    })?;
            }
            if let Some(r) = args.get("router") {
                let spec = carbonedge::site::RouterSpec::parse(r).ok_or_else(|| {
                    anyhow::anyhow!("unknown --router {r:?}; try nearest|carbon|deadline")
                })?;
                match sc.sites.as_mut() {
                    Some(layer) => layer.router = spec,
                    None => anyhow::bail!(
                        "--router needs a site layer: use --scenario multi-site or \
                         follow-the-sun"
                    ),
                }
            }
            if let Some(w) = args.get("idle-w") {
                let w: f64 = w
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--idle-w expects watts, got {w:?}"))?;
                if !w.is_finite() || w < 0.0 {
                    anyhow::bail!("--idle-w must be finite and >= 0");
                }
                for spec in &mut sc.specs {
                    spec.idle_w = w;
                }
            }
            // Any microgrid knob equips *every* node with a PV + battery
            // microgrid built from the flags (replacing whatever the
            // scenario shipped): --pv-peak-w gives a diurnal half-sine
            // array, --pv-csv a trace-driven one (watts), --battery-wh a
            // 1C battery starting half-charged.
            let mg_knobs = ["pv-peak-w", "pv-csv", "battery-wh", "battery-rt-eff"];
            if mg_knobs.iter().any(|f| args.has(f)) {
                if args.has("pv-peak-w") && args.has("pv-csv") {
                    anyhow::bail!("--pv-peak-w and --pv-csv are mutually exclusive");
                }
                let mut supplies_anything = args.has("pv-csv");
                let pv = if let Some(path) = args.get("pv-csv") {
                    let text = std::fs::read_to_string(path)
                        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
                    carbonedge::microgrid::PvProfile::from_csv(&text)
                        .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?
                } else {
                    let peak: f64 = args.parse_or("pv-peak-w", 0.0f64)?;
                    if !peak.is_finite() || peak < 0.0 {
                        anyhow::bail!("--pv-peak-w must be finite and >= 0, got {peak}");
                    }
                    supplies_anything |= peak > 0.0;
                    carbonedge::microgrid::PvProfile::diurnal(peak)
                };
                let battery_wh: f64 = args.parse_or("battery-wh", 0.0f64)?;
                let rt_eff: f64 = args.parse_or("battery-rt-eff", 0.9f64)?;
                // A microgrid that supplies nothing would still flip every
                // node onto the slice-settled accounting path (and grow the
                // report with all-zero supply columns): reject it instead.
                if !supplies_anything && battery_wh == 0.0 {
                    anyhow::bail!(
                        "microgrid flags supply nothing: give --pv-peak-w > 0, --pv-csv, \
                         or --battery-wh > 0"
                    );
                }
                let battery =
                    carbonedge::microgrid::BatterySpec::simple(battery_wh, rt_eff, 0.5);
                let spec = carbonedge::microgrid::MicrogridSpec {
                    pv,
                    battery,
                    charge: carbonedge::microgrid::ChargePolicy::Off,
                };
                if let Err(e) = spec.validate() {
                    anyhow::bail!("bad microgrid flags: {e}");
                }
                sc.microgrids = vec![Some(spec); sc.specs.len()];
            }
            // Grid-charge arbitrage knobs: retune (or disable) the charge
            // policy on every microgrid node. `--charge-threshold-pct`
            // alone implies the threshold policy.
            if args.has("charge-policy") || args.has("charge-threshold-pct") {
                if sc.microgrids.is_empty() {
                    anyhow::bail!(
                        "--charge-policy needs microgrids: use a microgrid scenario \
                         (arbitrage, solar-battery, microgrid-fleet) or \
                         --pv-peak-w/--battery-wh"
                    );
                }
                let policy_name = args.str_or("charge-policy", "threshold");
                let policy = match policy_name.as_str() {
                    "off" => {
                        if args.has("charge-threshold-pct") {
                            anyhow::bail!(
                                "--charge-policy off does not combine with \
                                 --charge-threshold-pct"
                            );
                        }
                        carbonedge::microgrid::ChargePolicy::Off
                    }
                    "threshold" => {
                        let pct: f64 = args.parse_or(
                            "charge-threshold-pct",
                            carbonedge::microgrid::DEFAULT_CHARGE_PERCENTILE * 100.0,
                        )?;
                        if !pct.is_finite() || !(0.0 < pct && pct < 100.0) {
                            anyhow::bail!(
                                "--charge-threshold-pct expects a percentile in (0, 100), \
                                 got {pct}"
                            );
                        }
                        carbonedge::microgrid::ChargePolicy::threshold(pct / 100.0)
                    }
                    other => {
                        anyhow::bail!("unknown --charge-policy {other:?}; try off|threshold")
                    }
                };
                for mg in sc.microgrids.iter_mut().flatten() {
                    mg.charge = policy.clone();
                }
            }
            if args.bool_flag("compare-microgrid") {
                // This arm runs its own fixed green-mode A/B and returns:
                // any other run-shaping knob would be silently ignored —
                // reject loudly instead (the --consolidate precedent).
                let conflicts = [
                    "mode",
                    "scheduler",
                    "step",
                    "slack",
                    "headroom",
                    "defer-resolution",
                    "defer-min-gain",
                    "trace-out",
                    "trace-filter",
                    "timeline-stride",
                    "monitor",
                    "telemetry-out",
                    "batch-window-ms",
                    "batch-max",
                ];
                for flag in conflicts {
                    if args.has(flag) {
                        anyhow::bail!("--compare-microgrid does not combine with --{flag}");
                    }
                }
                let switches = [
                    "sweep",
                    "json",
                    "no-defer",
                    "compare-defer",
                    "compare-defer-routing",
                    "compare-arbitrage",
                    "compare-batching",
                    "compare-routers",
                ];
                for switch in switches {
                    if args.bool_flag(switch) {
                        anyhow::bail!("--compare-microgrid does not combine with --{switch}");
                    }
                }
                if sc.microgrids.is_empty() {
                    anyhow::bail!(
                        "--compare-microgrid needs microgrids: use a microgrid scenario \
                         (solar-battery, microgrid-fleet) or --pv-peak-w/--battery-wh"
                    );
                }
                let (mg_green, plain_green, mg_rr) = exp::sim_microgrid_comparison(&sc);
                println!("{}", exp::sim_microgrid_render(&mg_green, &plain_green, &mg_rr));
                return Ok(());
            }
            let defer_knobs =
                ["slack", "headroom", "defer-resolution", "defer-min-gain"];
            if args.bool_flag("no-defer") {
                sc.config.deferral = None;
            } else if defer_knobs.iter().any(|f| args.has(f)) {
                // Any single knob tunes the scenario's existing deferral
                // (real-trace defaults) or enables it from the defaults —
                // `--defer-min-gain` alone must not be silently ignored.
                // Validate here so bad knob values are clean CLI errors,
                // not library assert panics mid-run.
                let base = sc.config.deferral.clone().unwrap_or_default();
                let slack_s = args.parse_or("slack", base.slack_s)?;
                let headroom_s = args.parse_or("headroom", base.headroom_s)?;
                let resolution_s = args.parse_or("defer-resolution", base.policy.resolution_s)?;
                let min_gain = args.parse_or("defer-min-gain", base.policy.min_gain)?;
                if !slack_s.is_finite() || slack_s < 0.0 || !headroom_s.is_finite() || headroom_s < 0.0 {
                    anyhow::bail!("--slack and --headroom must be finite and >= 0");
                }
                if !resolution_s.is_finite() || resolution_s <= 0.0 {
                    anyhow::bail!("--defer-resolution must be > 0, got {resolution_s}");
                }
                if !min_gain.is_finite() || !(0.0..=1.0).contains(&min_gain) {
                    anyhow::bail!("--defer-min-gain must be in [0, 1], got {min_gain}");
                }
                sc.config.deferral = Some(carbonedge::sim::DeferralSpec {
                    slack_s,
                    headroom_s,
                    policy: carbonedge::carbon::DeferralPolicy { resolution_s, min_gain },
                });
            }
            // Batch-formation knobs: either one tunes the scenario's
            // existing batch spec or enables batching from the defaults
            // (window 200 ms, fill 8) — `--batch-max` alone must not be
            // silently ignored.
            let batch_knobs = ["batch-window-ms", "batch-max"];
            if batch_knobs.iter().any(|f| args.has(f)) {
                let base = sc.config.batching.unwrap_or_default();
                let window_ms: f64 = args.parse_or("batch-window-ms", base.window_ms)?;
                let max_batch: usize = args.parse_or("batch-max", base.max_batch)?;
                if !window_ms.is_finite() || window_ms < 0.0 {
                    anyhow::bail!("--batch-window-ms must be finite and >= 0, got {window_ms}");
                }
                if max_batch == 0 {
                    anyhow::bail!("--batch-max must be >= 1");
                }
                sc.config.batching = Some(carbonedge::sim::BatchSpec { window_ms, max_batch });
            }
            // Everything above mutated the scenario from CLI knobs: validate
            // once here so any bad combination is a clean error, never a
            // mid-simulation panic.
            sc.validate().map_err(|e| anyhow::anyhow!("invalid scenario configuration: {e}"))?;
            // The firehose, monitors and telemetry export all document
            // exactly one simulation run; the comparison arms run several
            // and would interleave their events into one stream.
            let single_run_flag = if trace_out.is_some() {
                Some("trace-out")
            } else if monitors.is_some() {
                Some("monitor")
            } else if telemetry_out.is_some() {
                Some("telemetry-out")
            } else {
                None
            };
            if let Some(flag) = single_run_flag {
                for switch in [
                    "sweep",
                    "compare-defer",
                    "compare-defer-routing",
                    "compare-arbitrage",
                    "compare-batching",
                    "compare-routers",
                ] {
                    if args.bool_flag(switch) {
                        anyhow::bail!(
                            "--{flag} documents one run; it does not combine with --{switch}"
                        );
                    }
                }
            }
            if args.bool_flag("compare-arbitrage") {
                if sc.microgrids.is_empty()
                    || sc.microgrids.iter().flatten().all(|m| m.charge.is_off())
                {
                    anyhow::bail!(
                        "--compare-arbitrage needs a grid-charge policy: use \
                         --scenario arbitrage or --charge-policy threshold"
                    );
                }
                if sc.config.deferral.is_none() {
                    anyhow::bail!(
                        "--compare-arbitrage needs deferral on: use --slack or the \
                         arbitrage scenario"
                    );
                }
                if args.has("mode") || args.has("scheduler") {
                    anyhow::bail!(
                        "--compare-arbitrage always runs the defer-green scheduler; it \
                         does not combine with --mode/--scheduler"
                    );
                }
                for switch in ["sweep", "json", "no-defer", "compare-defer", "compare-defer-routing"] {
                    if args.bool_flag(switch) {
                        anyhow::bail!("--compare-arbitrage does not combine with --{switch}");
                    }
                }
                let (arb, off, frozen) = exp::sim_arbitrage_comparison(&sc);
                println!("{}", exp::sim_arbitrage_render(&arb, &off, &frozen));
                return Ok(());
            }
            if args.bool_flag("compare-defer") {
                if sc.config.deferral.is_none() {
                    anyhow::bail!(
                        "--compare-defer needs deferral on: use --slack or a deferral \
                         scenario like real-trace"
                    );
                }
                if args.has("scheduler") {
                    anyhow::bail!(
                        "--compare-defer always runs green mode; it does not combine \
                         with --scheduler (try --compare-defer-routing)"
                    );
                }
                let (deferred, baseline) = exp::sim_deferral_comparison(&sc);
                println!("{}", exp::sim_deferral_render(&deferred, &baseline));
                return Ok(());
            }
            if args.bool_flag("compare-defer-routing") {
                if sc.config.deferral.is_none() {
                    anyhow::bail!(
                        "--compare-defer-routing needs deferral on: use --slack or a \
                         deferral scenario like deferral-routing"
                    );
                }
                if args.has("mode") || args.has("scheduler") || args.bool_flag("sweep") {
                    anyhow::bail!(
                        "--compare-defer-routing does not combine with --mode/--scheduler/--sweep"
                    );
                }
                let (joint, rtd) = exp::sim_deferral_routing_comparison(&sc);
                println!("{}", exp::sim_deferral_routing_render(&joint, &rtd));
                return Ok(());
            }
            if args.bool_flag("compare-batching") {
                if sc.config.batching.is_none() {
                    anyhow::bail!(
                        "--compare-batching needs batch formation on: use --scenario \
                         batch-serving / multi-tenant or --batch-window-ms/--batch-max"
                    );
                }
                if args.has("mode") || args.has("scheduler") {
                    anyhow::bail!(
                        "--compare-batching always runs green mode; it does not combine \
                         with --mode/--scheduler"
                    );
                }
                for switch in ["sweep", "json", "no-defer", "compare-defer"] {
                    if args.bool_flag(switch) {
                        anyhow::bail!("--compare-batching does not combine with --{switch}");
                    }
                }
                let (batched, unbatched) = exp::sim_batching_comparison(&sc);
                println!("{}", exp::sim_batching_render(&batched, &unbatched));
                return Ok(());
            }
            if args.bool_flag("compare-routers") {
                if sc.sites.is_none() {
                    anyhow::bail!(
                        "--compare-routers needs a site layer: use --scenario multi-site \
                         or follow-the-sun"
                    );
                }
                if args.has("mode") || args.has("scheduler") || args.has("router") {
                    anyhow::bail!(
                        "--compare-routers runs all three routers under the scenario's \
                         own scheduler; it does not combine with \
                         --mode/--scheduler/--router"
                    );
                }
                for switch in ["sweep", "json", "no-defer", "compare-defer"] {
                    if args.bool_flag(switch) {
                        anyhow::bail!("--compare-routers does not combine with --{switch}");
                    }
                }
                let reports = exp::sim_router_comparison(&sc);
                println!("{}", exp::sim_router_render(&reports));
                return Ok(());
            }
            if args.bool_flag("sweep") {
                let step = args.parse_or("step", 0.1f64)?;
                if !(step > 0.0 && step <= 1.0) {
                    anyhow::bail!("--step must be in (0, 1], got {step}");
                }
                let points = exp::sim_weight_sweep(&sc, step);
                println!("{}", exp::sim_sweep_render(&points));
            } else if let Some(sched_name) = args.get("scheduler") {
                if args.has("mode") {
                    anyhow::bail!("--scheduler and --mode are mutually exclusive");
                }
                let mut sched = sim_scheduler(sched_name, seed, &sc)?;
                run_sim_single(
                    &sc,
                    sched.as_mut(),
                    args.bool_flag("json"),
                    timeline_stride,
                    trace_out.as_deref(),
                    trace_filter,
                    monitors,
                    telemetry_out.as_deref(),
                )?;
            } else if let Some(mode_s) = args.get("mode") {
                let mode = Mode::parse(mode_s).ok_or_else(|| anyhow::anyhow!("bad --mode"))?;
                let mut sched = CarbonAwareScheduler::new(mode.name(), mode.weights());
                run_sim_single(
                    &sc,
                    &mut sched,
                    args.bool_flag("json"),
                    timeline_stride,
                    trace_out.as_deref(),
                    trace_filter,
                    monitors,
                    telemetry_out.as_deref(),
                )?;
            } else if single_run_flag.is_some() {
                // Instrumentation needs one concrete run to document:
                // default to green mode (the headline CE configuration)
                // instead of the four-way mode comparison.
                let mut sched = CarbonAwareScheduler::new("green", Mode::Green.weights());
                run_sim_single(
                    &sc,
                    &mut sched,
                    args.bool_flag("json"),
                    timeline_stride,
                    trace_out.as_deref(),
                    trace_filter,
                    monitors,
                    telemetry_out.as_deref(),
                )?;
            } else {
                let reports = exp::sim_mode_comparison(&sc);
                println!("{}", exp::sim_comparison_render(&reports));
            }
        }
        "replay" => {
            // Pure trace processing — no artifacts, no Coordinator. The
            // NDJSON firehose is the only input; an `all`-filter trace is a
            // complete ledger and folds back into the full report.
            let open = |p: &str| -> Result<std::io::BufReader<std::fs::File>> {
                Ok(std::io::BufReader::new(
                    std::fs::File::open(p).map_err(|e| anyhow::anyhow!("opening {p}: {e}"))?,
                ))
            };
            if args.bool_flag("diff") {
                let (a, b) = match args.positional.as_slice() {
                    [a, b] => (a.as_str(), b.as_str()),
                    _ => anyhow::bail!("replay --diff expects exactly two trace paths"),
                };
                match carbonedge::obs::replay::diff(open(a)?, open(b)?)
                    .map_err(|e| anyhow::anyhow!("diffing {a} vs {b}: {e}"))?
                {
                    None => println!("traces agree: no divergent event"),
                    Some(d) => anyhow::bail!("traces diverge: {}", d.render()),
                }
                return Ok(());
            }
            let path = match args.positional.as_slice() {
                [p] => p.as_str(),
                _ => anyhow::bail!("replay expects one trace path (or --diff A B)"),
            };
            let (report, events) = carbonedge::obs::replay::replay_report(open(path)?)
                .map_err(|e| anyhow::anyhow!("replaying {path}: {e}"))?;
            eprintln!("replay: {events} events from {path}");
            if args.bool_flag("verify") {
                // The run_meta header makes the trace self-describing:
                // rebuild the library scenario it names, re-run it live on
                // the same seed and scheduler, and audit the replayed
                // report against the fresh one. Only unmodified library
                // scenarios round-trip — CLI-mutated runs (--idle-w,
                // --slack, microgrid flags...) name a scenario the library
                // cannot rebuild verbatim.
                let sc = carbonedge::sim::scenarios::build(
                    &report.scenario,
                    report.nodes.len(),
                    report.requests as usize,
                    report.seed,
                )
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "trace names scenario {:?}, which is not in the library; \
                         --verify only replays unmodified library scenarios",
                        report.scenario
                    )
                })?;
                let mut sched = sim_scheduler(&report.scheduler, report.seed, &sc)?;
                let live = carbonedge::sim::Simulation::try_run(&sc, sched.as_mut())
                    .map_err(|e| anyhow::anyhow!("invalid scenario: {e}"))?;
                let mismatches = carbonedge::obs::replay::verify(&report, &live);
                if mismatches.is_empty() {
                    eprintln!(
                        "verify: replayed report matches the live {} / {} / seed {} run",
                        report.scenario, report.scheduler, report.seed
                    );
                } else {
                    for m in &mismatches {
                        eprintln!("verify: {m}");
                    }
                    anyhow::bail!(
                        "replayed report diverges from the live run in {} field(s)",
                        mismatches.len()
                    );
                }
            }
            if args.bool_flag("json") {
                println!("{}", carbonedge::metrics::sim_report_json_string(&report));
            } else {
                println!("{}", report.render());
            }
        }
        "lint" => {
            // Static determinism/ledger-safety gate (no artifacts, no sim
            // work): walk the source tree, print unwaived findings, and —
            // under --deny — fail the process so CI blocks the merge.
            let paths: Vec<String> = if args.positional.is_empty() {
                vec!["rust/src".to_string()]
            } else {
                args.positional.clone()
            };
            let report = carbonedge::analysis::lint_paths(&paths)?;
            for f in &report.findings {
                println!("{f}");
            }
            eprintln!(
                "lint: {} file(s), {} unwaived finding(s), {} waived",
                report.files,
                report.findings.len(),
                report.waived
            );
            if args.bool_flag("deny") && !report.findings.is_empty() {
                anyhow::bail!("lint --deny: {} unwaived finding(s)", report.findings.len());
            }
        }
        other => {
            anyhow::bail!(
                "unknown command {other:?}; try info|golden|serve|reproduce|sweep|overhead|baselines|sim|replay|lint"
            );
        }
    }
    Ok(())
}

/// Build a named simulator scheduler. Shared by `sim --scheduler` and
/// `replay --verify` (which reconstructs the scheduler a trace's run_meta
/// header names).
fn sim_scheduler(
    name: &str,
    seed: u64,
    sc: &carbonedge::sim::Scenario,
) -> Result<Box<dyn Scheduler>> {
    Ok(match name {
        "defer-green" => {
            // Joint defer+route: reuse the scenario's min-gain knob so
            // `--defer-min-gain` shapes both verdicts.
            let min_gain = sc
                .config
                .deferral
                .as_ref()
                .map(|d| d.policy.min_gain)
                .unwrap_or_else(|| carbonedge::carbon::DeferralPolicy::default().min_gain);
            Box::new(carbonedge::scheduler::DeferAwareGreenScheduler::new(min_gain))
        }
        "green" | "balanced" | "performance" | "perf" => {
            let mode = Mode::parse(name).unwrap();
            Box::new(CarbonAwareScheduler::new(mode.name(), mode.weights()))
        }
        "round-robin" => Box::new(carbonedge::scheduler::RoundRobinScheduler::new()),
        "random" => Box::new(carbonedge::scheduler::RandomScheduler::new(seed)),
        "least-loaded" => Box::new(carbonedge::scheduler::LeastLoadedScheduler),
        "amp4ec" => Box::new(Amp4ecScheduler::new()),
        other => anyhow::bail!(
            "unknown scheduler {other:?}; try defer-green|green|balanced|\
             performance|round-robin|random|least-loaded|amp4ec"
        ),
    })
}

/// Run one scheduler over the scenario — optionally streaming the NDJSON
/// event firehose to `trace_out`, evaluating in-sim `monitors`, and writing
/// the telemetry registry to `telemetry_out` — and print the report.
/// Telemetry and the trace summary go to stderr so `--json` stdout stays
/// machine-parseable. With monitors or a telemetry export but no trace
/// path, the run is instrumented against a [`carbonedge::obs::NullSink`]
/// (counters only); with none of the three, nothing is ever constructed.
fn run_sim_single(
    sc: &carbonedge::sim::Scenario,
    sched: &mut dyn Scheduler,
    json: bool,
    timeline_stride: usize,
    trace_out: Option<&str>,
    trace_filter: carbonedge::obs::TraceFilter,
    monitors: Option<carbonedge::obs::MonitorSet>,
    telemetry_out: Option<&str>,
) -> Result<()> {
    use carbonedge::sim::Simulation;
    let bad = |e: String| anyhow::anyhow!("invalid scenario: {e}");
    let (report, telem) = match trace_out {
        Some(path) => {
            let file = std::fs::File::create(path)
                .map_err(|e| anyhow::anyhow!("creating {path}: {e}"))?;
            let mut sink = carbonedge::obs::FirehoseSink::with_filter(
                std::io::BufWriter::new(file),
                trace_filter,
            );
            let (report, telem) = match monitors {
                Some(m) => Simulation::try_run_monitored(sc, sched, &mut sink, m),
                None => Simulation::try_run_observed(sc, sched, &mut sink),
            }
            .map_err(bad)?;
            let events = sink.events_written();
            let buf = sink.finish().map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            buf.into_inner().map_err(|e| anyhow::anyhow!("flushing {path}: {e}"))?;
            eprint!("{}", telem.render());
            eprintln!("trace: {events} events -> {path}");
            (report, Some(telem))
        }
        None if monitors.is_some() || telemetry_out.is_some() => {
            let mut sink = carbonedge::obs::NullSink;
            let (report, telem) = match monitors {
                Some(m) => Simulation::try_run_monitored(sc, sched, &mut sink, m),
                None => Simulation::try_run_observed(sc, sched, &mut sink),
            }
            .map_err(bad)?;
            eprint!("{}", telem.render());
            (report, Some(telem))
        }
        None => (Simulation::try_run(sc, sched).map_err(bad)?, None),
    };
    if let Some(path) = telemetry_out {
        let telem = telem.as_ref().expect("an instrumented run always yields telemetry");
        let mut buf = Vec::new();
        {
            let mut j = carbonedge::util::json::JsonWriter::new(&mut buf);
            telem.write_json(&mut j).map_err(|e| anyhow::anyhow!("serializing telemetry: {e}"))?;
        }
        buf.push(b'\n');
        std::fs::write(path, &buf).map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        eprintln!("telemetry -> {path}");
    }
    if json {
        println!(
            "{}",
            carbonedge::metrics::sim_report_json_string_strided(&report, timeline_stride)
        );
    } else {
        println!("{}", report.render());
    }
    Ok(())
}

fn print_usage() {
    println!(
        "\
carbonedge — carbon-aware edge inference (CarbonEdge reproduction)

  carbonedge info                                  platform + manifest summary
  carbonedge golden [--model NAME]                 end-to-end numerics gate
  carbonedge serve --model NAME --mode green ...   serve a workload, print report
  carbonedge reproduce [--table 2|3|4|5] [--fig 2|3] [--all]
  carbonedge sweep [--step 0.05] [--iters 20]      Fig. 3 weight sweep
  carbonedge overhead                              scheduling overhead micro-report
  carbonedge baselines                             scheduler ablation
  carbonedge sim --help                            virtual-time fleet simulator
  carbonedge replay TRACE [--verify] [--json]      reconstruct a sim report from an
                                                   NDJSON trace (--verify audits it
                                                   against a fresh live run)
  carbonedge replay --diff A B                     first divergent event between two
                                                   traces (determinism debugging)
  carbonedge lint [--deny] [PATHS]                 determinism & ledger-safety static
                                                   analysis over the simulator source
                                                   (default rust/src; --deny exits
                                                   nonzero on unwaived findings)"
    );
}

fn print_sim_help() {
    println!(
        "\
carbonedge sim — virtual-time fleet simulator (no artifacts needed)

  --scenario NAME        scenario to run (default paper-3-node)
  --list-scenarios       print the scenario names and exit
  --nodes N              fleet-size override (0 = scenario default)
  --requests M           request count (0 = 20000)
  --seed S               master seed (default 42)
  --mode MODE            run one CE mode (green|balanced|performance); default
                         runs the monolithic baseline plus all three modes
  --scheduler NAME       run one scheduler instead: defer-green (joint
                         defer+route over the fleet forecast), green,
                         balanced, performance, round-robin, random,
                         least-loaded, amp4ec
  --json                 with --mode/--scheduler: emit the report as JSON
  --sweep [--step F]     w_C weight sweep instead of a mode run

energy model:
  --idle-w W             set every node's idle-floor draw to W watts; idle
                         energy accrues over virtual uptime, integrated
                         against each node's intensity trace (report splits
                         energy into idle + dynamic)
  --consolidate LARGE    idle-floor A/B: replay the same workload on a small
                         fleet (--nodes, default 3) and on LARGE nodes

microgrids (any knob puts a PV + battery microgrid behind every node;
draw is covered PV-first, then battery, then grid, and schedulers score
the marginal effective intensity — what the next task's watts would pay
after the standing draw claims local supply):
  --pv-peak-w W          diurnal half-sine PV array peaking at W watts
                         (sunrise 06:00, solar noon 12:00)
  --pv-csv PATH          PV generation trace instead (timestamp,watts CSV)
  --battery-wh WH        1C battery of WH watt-hours, starting half-charged
  --battery-rt-eff F     round-trip efficiency in (0, 1] (default 0.9)
  --compare-microgrid    A/B: green mode with microgrids, the grid-only
                         twin, and carbon-agnostic round-robin

grid-charge arbitrage (batteries may buy cheap clean grid energy; stored
joules carry their embodied carbon and release it on discharge — never
laundered to zero):
  --charge-policy P      off, or threshold: charge from the grid whenever
                         the trace sits in the cleanest fraction of its
                         day-ahead window (the arbitrage scenario defaults
                         to threshold)
  --charge-threshold-pct P
                         the threshold percentile, in percent (default 25)
  --compare-arbitrage    A/B/C under defer-green: arbitrage + SoC-trajectory
                         forecasts vs the charge-off twin vs the
                         charge-frozen-forecast twin

carbon deferral (any knob enables deferral, or tunes a scenario that
defers by default, like real-trace):
  --slack S              give every arrival S seconds of deadline slack and
                         let the in-engine policy park work for cleaner slots
  --headroom S           safety margin kept before the deadline (default 900)
  --defer-resolution S   forecast sampling resolution (default 300)
  --defer-min-gain F     minimum relative gain to defer (default 0.05)
  --no-defer             strip deferral from scenarios that default to it
  --compare-defer        run the scenario with and without deferral, report
                         the gCO2/req delta and deadline misses
  --compare-defer-routing
                         A/B the joint defer-green scheduler against the
                         legacy route-then-defer gate on the same workload
                         (the deferral-routing scenario is built for it)

batched multi-tenant serving (tasks of the same workload class batch up
per node and run as one batch in one service slot, on the chassis's
sub-linear batch latency/power curves; the batch-serving and
multi-tenant scenarios ship a tenant mix and batch on by default):
  --batch-window-ms MS   longest wait before a forming batch seals
                         regardless of fill (default 200; 0 seals
                         immediately). Either batch knob enables batching
                         on scenarios that ship without it
  --batch-max N          fill target: a batch seals at N same-class tasks
                         and never carries more (default 8; 1 restores
                         one-task-per-slot service exactly)
  --compare-batching     A/B in green mode: the batched scenario against
                         its one-task-per-slot twin (same tenant mix,
                         arrivals and seed), reporting the gCO2/req and
                         p99 gap

multi-site fleets (the multi-site and follow-the-sun scenarios group
nodes into regional sites with staggered diurnal grids; a cross-site
router ships each arrival to the region whose grid/PV should eat it,
pricing the WAN hop into both latency and carbon):
  --sites N              rebuild the region roster with N sites, timezones
                         spread uniformly over the day (default 3; node
                         count defaults to three per region)
  --router NAME          cross-site policy: nearest (locality only),
                         carbon (greedy cleanest region), deadline
                         (cleanest region that still clears the SLO after
                         the WAN hop; the default)
  --compare-routers      A/B/C all three routers on the same fleet,
                         arrivals and seed, reporting gCO2/req, shipped
                         share, WAN energy and missed deadlines

real traces:
  --trace-csv PATH       with --scenario real-trace: load an
                         ElectricityMaps-style CSV (timestamp[,zone],gCO2/kWh)
                         instead of the bundled synthetic day

observability (single runs only — with neither --mode nor --scheduler,
these default to one green-mode run):
  --trace-out PATH       stream the event firehose to PATH as NDJSON, one
                         event per line: run_meta (the self-describing
                         header), arrival, decision (with per-candidate
                         scores and reject reasons), dispatch,
                         defer_release, completion, churn, batch_formed,
                         mg_slice, idle_slice, alert; telemetry (event
                         counts, queue-delay/latency histograms,
                         per-decision overhead vs the paper's 0.03 ms
                         envelope) prints to stderr. An 'all'-filter trace
                         is a complete ledger: `carbonedge replay` folds it
                         back into the full report
  --trace-filter KINDS   keep only these event kinds: 'all' or a comma list
                         of run_meta,arrival,decision,dispatch,
                         defer_release,completion,churn,batch_formed,
                         mg_slice,idle_slice,alert
  --monitor SPEC         attach in-sim monitors evaluated on every emitted
                         event over sliding virtual-time windows: a comma
                         list of carbon-budget=G (gCO2/s burn rate),
                         slo-burn=PCT (per-class SLO-miss rate),
                         reject-defer=PCT (reject/defer rate) and window=S
                         (shared window, default 3600). Threshold crossings
                         fire 'alert' events into the firehose; per-rule
                         summaries land in the report and telemetry. Works
                         without --trace-out (counters only)
  --telemetry-out PATH   write the run's telemetry registry (event counts,
                         histograms, overhead envelope, monitor summaries)
                         to PATH as JSON
  --timeline-stride N    with --json: downsample the per-node intensity and
                         SoC timelines to every Nth sample (first and last
                         kept)"
    );
}

fn print_report(r: &RunReport) {
    println!(
        "{:<18} {:>4} inf  latency {:.2} ms (p95 {:.2})  {:.2} req/s  {:.5} gCO2/inf  {:.1} inf/g",
        r.label,
        r.inferences,
        r.latency_ms.mean,
        r.latency_ms.p95,
        r.throughput_rps,
        r.carbon_per_inf_g,
        r.carbon_efficiency
    );
    for (n, c) in &r.node_usage {
        println!("    {n}: {c} tasks");
    }
}
