//! Bench: scheduling overhead (paper Sec. IV-F: 0.03 ms/task, <1% CPU).
//! Micro-benchmarks the node-selection hot path in isolation plus the
//! in-situ overhead measured inside a real run.

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::experiments as exp;
use carbonedge::node::NodeRegistry;
use carbonedge::scheduler::{CarbonAwareScheduler, FleetView, Mode, Scheduler, TaskDemand};
use carbonedge::util::bench::{black_box, Bencher};

fn main() -> anyhow::Result<()> {
    // Isolated: snapshot + Algorithm-1 decision over the 3-node fleet —
    // the full per-task scheduling cost of the decide API.
    let registry = NodeRegistry::paper_setup();
    let task = TaskDemand::default();
    let b = Bencher::default();
    for mode in Mode::all() {
        let mut s = CarbonAwareScheduler::new(mode.name(), mode.weights());
        let r = b.run_batched(&format!("nsa-decide/{}", mode.name()), 1000, || {
            let fleet = FleetView::observe(registry.nodes());
            black_box(s.decide(&task, &fleet));
        });
        println!("{}", r.report());
    }

    // Scaling: decision cost vs fleet size.
    for n in [3usize, 10, 50, 100] {
        let specs: Vec<_> = (0..n)
            .map(|i| {
                let mut spec = carbonedge::node::NodeSpec::paper_nodes()[i % 3].clone();
                spec.name = format!("n{i}");
                spec
            })
            .collect();
        let reg = NodeRegistry::new(specs);
        let mut s = CarbonAwareScheduler::new("green", Mode::Green.weights());
        let r = b.run_batched(&format!("nsa-decide/fleet-{n}"), 500, || {
            let fleet = FleetView::observe(reg.nodes());
            black_box(s.decide(&task, &fleet));
        });
        println!("{}", r.report());
    }

    // In-situ: measured inside a real scheduled run (includes lock traffic).
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let coord = Coordinator::new(Config::default())?;
        let s = exp::scheduling_overhead(&coord, "mobilenet_v2", 50)?;
        println!(
            "in-situ scheduling overhead: mean {:.4} ms, p95 {:.4} ms (paper: 0.03 ms)",
            s.mean, s.p95
        );
    } else {
        println!("(skipping in-situ overhead: run `make artifacts`)");
    }
    Ok(())
}
