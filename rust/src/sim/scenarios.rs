//! The scenario library: named, parameterized fleet/workload setups for
//! `carbonedge sim --scenario <name>`. Every scenario is deterministic in
//! `(nodes, requests, seed)`.
//!
//! * **`paper-3-node`** — the paper's Sec. IV-A1 testbed (node-high /
//!   node-medium / node-green, static grids) replayed open-loop at 6 req/s,
//!   enough pressure that modes genuinely contend for nodes instead of the
//!   closed-loop 100%-concentration regime of Table V.
//! * **`fleet-100`** — an N-node (default 100) heterogeneous fleet
//!   synthesized from the `REGIONS` table ([`crate::sim::fleet`]), Poisson
//!   arrivals at 60% of aggregate service capacity: the scale regime where
//!   carbon-aware scoring has real routing freedom.
//! * **`diurnal-solar`** — N nodes (default 12) whose grids follow
//!   [`IntensityTrace::Diurnal`] (amplitude 40% of the regional mean) over a
//!   six-hour virtual horizon; exercises time-varying intensity on both the
//!   scheduling and the accounting path.
//! * **`bursty`** — the paper's 3 nodes under a two-state MMPP arrival
//!   process (quiet 25% / burst 150% of fleet capacity, 20 s mean dwell):
//!   queueing behaviour under load spikes.
//! * **`churn`** — an N-node fleet (default 10) where one node is down from
//!   t = 0 and a third of the fleet departs mid-run and returns later;
//!   queued work migrates, and nothing may ever be scheduled onto a
//!   departed node.
//! * **`real-trace`** — nodes driven by a real-shape (ElectricityMaps-style
//!   CSV) day of hourly grid intensities for three zones, arrivals over the
//!   first half-day, and **in-engine deferral on by default** (6 h slack):
//!   morning-peak work parks until the midday solar trough.
//! * **`deferral-routing`** — the `real-trace` zone fleet with one
//!   service slot per node and ~1 s tasks: enough contention that routing
//!   spills across zones and parked work can stampede the clean zone's
//!   trough. Built for the joint defer+route A/B
//!   ([`crate::experiments::sim_deferral_routing_comparison`],
//!   `--compare-defer-routing`, `--scheduler defer-green`).
//! * **`consolidation`** — an N-node (default 12) fleet of identical
//!   idle-capable hosts ([`crate::energy::HostPowerModel`] split: ≈142 W
//!   rated / ≈54 W idle floor) under a load only ~3 nodes' worth: run it at
//!   two fleet sizes to watch idle floors dominate — fewer busy nodes beat
//!   many idle ones ([`crate::experiments::sim_consolidation`]).
//! * **`solar-battery`** — an N-node (default 4) fleet of identical
//!   idle-capable hosts on a static 475 g/kWh grid, each behind a PV +
//!   battery microgrid (400 W peak half-sine array, 600 Wh 1C battery
//!   starting overnight-depleted at 30%), arrivals spread over one virtual
//!   day: daytime draw is PV-covered, the battery bridges the evening, and
//!   only the pre-dawn hours import grid power
//!   ([`crate::experiments::sim_microgrid`] runs the grid-only A/B).
//! * **`microgrid-fleet`** — an N-node (default 12) heterogeneous
//!   `REGIONS` fleet where every *even-indexed* node carries a microgrid
//!   (PV staggered across "longitudes", a well-charged battery); under a
//!   carbon-aware mode the blended effective intensities steer load toward
//!   the charged/sunlit half of the fleet.
//! * **`arbitrage`** — an N-node (default 4) idle-free fleet on a
//!   duck-curve grid (cheap clean night, dirty evening peak), each node
//!   behind a grid-chargeable battery
//!   ([`crate::microgrid::ChargePolicy::Threshold`]) with an
//!   inverter-limited discharge rate, deferral on (4 h slack), and the
//!   arrival *rate* pinned so battery dispatch timing is request-count
//!   invariant. Batteries fill overnight at ~150 g/kWh (carried at their
//!   embodied intensity by the stored-carbon ledger) and die mid-evening:
//!   the regime where charge-frozen forecasts defer work onto
//!   soon-to-be-empty batteries and SoC-trajectory forecasts do not
//!   ([`crate::experiments::sim_arbitrage_comparison`],
//!   `--compare-arbitrage`).
//! * **`batch-serving`** — an N-node (default 4) fleet of identical
//!   idle-capable serving hosts, one service slot each, under a
//!   three-tier tenant mix (interactive 3 s / standard 10 s /
//!   background 60 s SLOs) arriving at **130% of one-per-slot
//!   capacity**: unbatchable service drowns, while batch formation
//!   ([`BatchSpec`]: 200 ms window, fill 8) rides the chassis's
//!   sub-linear batch latency/power curve and absorbs the same load at
//!   lower gCO₂/req ([`crate::experiments::sim_batching_comparison`],
//!   `--compare-batching`).
//! * **`multi-tenant`** — an N-node (default 8) heterogeneous `REGIONS`
//!   fleet, microgrids on the even-indexed half, serving three tenants
//!   with *different models* (`exec_scale` 0.5/1/3), demands and
//!   priorities through per-`(node, class)` batch queues (window
//!   100 ms, fill 4), with demand-aware SoC projections on
//!   ([`SimConfig::demand_aware_projections`]): queued-but-unserved
//!   work depresses a microgrid's projected effective intensity before
//!   it is ever drawn.

use crate::carbon::{zone_traces_from_csv, IntensityTrace};
use crate::microgrid::{BatterySpec, ChargePolicy, DischargePolicy, MicrogridSpec, PvProfile};
use crate::node::NodeSpec;
use crate::scheduler::TaskDemand;
use crate::site::{
    RouterSpec, SiteLayer, SiteSpec, SiteTopology, WanLink, DEFAULT_REQUEST_BYTES,
    DEFAULT_WAN_J_PER_BYTE,
};
use crate::workload::{WorkloadClass, WorkloadMix};

use super::engine::{ArrivalProcess, BatchSpec, ChurnEvent, DeferralSpec, SimConfig};
use super::fleet;

/// Names accepted by [`build`] (and `carbonedge sim --scenario`).
pub const SCENARIO_NAMES: &[&str] = &[
    "paper-3-node",
    "fleet-100",
    "diurnal-solar",
    "bursty",
    "churn",
    "real-trace",
    "deferral-routing",
    "consolidation",
    "solar-battery",
    "microgrid-fleet",
    "arbitrage",
    "batch-serving",
    "multi-tenant",
    "multi-site",
    "follow-the-sun",
];

/// One synthetic ElectricityMaps-style day (hourly, 3 zones) bundled for
/// the `real-trace` scenario; `--trace-csv` substitutes a real export.
pub const BUNDLED_GRID_DAY_CSV: &str = include_str!("data/grid_day.csv");

/// A fully specified simulation setup.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub specs: Vec<NodeSpec>,
    /// Per-node intensity trace (same order as `specs`).
    pub traces: Vec<IntensityTrace>,
    /// Per-node service concurrency bound.
    pub capacity: Vec<usize>,
    pub arrivals: ArrivalProcess,
    /// Number of requests the arrival process generates.
    pub requests: usize,
    pub churn: Vec<ChurnEvent>,
    /// Optional PV + battery microgrid per node (same order as `specs`).
    /// Empty means "no microgrids anywhere"; otherwise one slot per node.
    pub microgrids: Vec<Option<MicrogridSpec>>,
    /// Optional geographic layer ([`crate::site`]): the site roster, the
    /// node→site partition, the WAN topology and the cross-site router.
    /// `None` (the default) is the flat single-region fleet.
    pub sites: Option<SiteLayer>,
    pub config: SimConfig,
}

impl Scenario {
    /// Validate every invariant the engine's hot paths rely on (shape,
    /// capacities, churn targets, microgrid specs, deferral knobs,
    /// config). Run once by [`super::Simulation::try_run`] before any
    /// event is processed — the hot paths themselves keep only
    /// `debug_assert!`s, so a bad scenario is a clean startup `Err`, not
    /// a mid-simulation panic.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.specs.len();
        if n == 0 {
            return Err("scenario needs at least one node".into());
        }
        if self.traces.len() != n {
            return Err(format!("{} traces for {n} nodes (need one per node)", self.traces.len()));
        }
        if self.capacity.len() != n {
            return Err(format!(
                "{} capacities for {n} nodes (need one per node)",
                self.capacity.len()
            ));
        }
        if let Some(i) = self.capacity.iter().position(|&c| c == 0) {
            return Err(format!("node {i} has zero service capacity"));
        }
        if !self.microgrids.is_empty() && self.microgrids.len() != n {
            return Err(format!(
                "{} microgrid slots for {n} nodes (need none, or one per node)",
                self.microgrids.len()
            ));
        }
        for (i, mg) in self.microgrids.iter().enumerate() {
            if let Some(mg) = mg {
                mg.validate().map_err(|e| format!("node {i} microgrid: {e}"))?;
            }
        }
        if let Some(layer) = &self.sites {
            layer.validate(n).map_err(|e| format!("site layer: {e}"))?;
        }
        for ev in &self.churn {
            if ev.node >= n {
                return Err(format!("churn event names node {} of {n}", ev.node));
            }
            if !ev.at_s.is_finite() || ev.at_s < 0.0 {
                return Err(format!("churn event at invalid time {}", ev.at_s));
            }
        }
        match &self.arrivals {
            ArrivalProcess::Uniform { rate_hz } | ArrivalProcess::Poisson { rate_hz } => {
                if !rate_hz.is_finite() || *rate_hz <= 0.0 {
                    return Err(format!("arrival rate must be > 0, got {rate_hz}"));
                }
            }
            ArrivalProcess::Mmpp { rate_low_hz, rate_high_hz, mean_dwell_s } => {
                for (name, v) in [
                    ("rate_low_hz", rate_low_hz),
                    ("rate_high_hz", rate_high_hz),
                    ("mean_dwell_s", mean_dwell_s),
                ] {
                    if !v.is_finite() || *v <= 0.0 {
                        return Err(format!("MMPP {name} must be > 0, got {v}"));
                    }
                }
            }
        }
        self.config.validate()
    }
}

/// Build a named scenario. `nodes == 0` and `requests == 0` select
/// per-scenario defaults. Returns `None` for unknown names.
pub fn build(name: &str, nodes: usize, requests: usize, seed: u64) -> Option<Scenario> {
    let requests = if requests == 0 { 20_000 } else { requests };
    match name {
        "paper-3-node" => Some(paper_3_node(requests, seed)),
        "fleet-100" => Some(fleet_n(if nodes == 0 { 100 } else { nodes }, requests, seed)),
        "diurnal-solar" => Some(diurnal_solar(if nodes == 0 { 12 } else { nodes }, requests, seed)),
        "bursty" => Some(bursty(nodes, requests, seed)),
        "churn" => Some(churn(if nodes == 0 { 10 } else { nodes }, requests, seed)),
        "real-trace" => Some(
            real_trace_from_csv(BUNDLED_GRID_DAY_CSV, nodes, requests, seed)
                .expect("bundled grid-day CSV is valid"), // lint: allow(P1 compile-time data)
        ),
        "deferral-routing" => Some(deferral_routing(nodes, requests, seed)),
        "consolidation" => {
            Some(consolidation(if nodes == 0 { 12 } else { nodes }, requests, seed))
        }
        "solar-battery" => {
            Some(solar_battery(if nodes == 0 { 4 } else { nodes }, requests, seed))
        }
        "microgrid-fleet" => {
            Some(microgrid_fleet(if nodes == 0 { 12 } else { nodes }, requests, seed))
        }
        "arbitrage" => Some(arbitrage(if nodes == 0 { 4 } else { nodes }, requests, seed)),
        "batch-serving" => {
            Some(batch_serving(if nodes == 0 { 4 } else { nodes }, requests, seed))
        }
        "multi-tenant" => {
            Some(multi_tenant(if nodes == 0 { 8 } else { nodes }, requests, seed))
        }
        "multi-site" => Some(multi_site(if nodes == 0 { 9 } else { nodes }, requests, seed)),
        "follow-the-sun" => {
            Some(follow_the_sun(if nodes == 0 { 9 } else { nodes }, requests, seed))
        }
        _ => None,
    }
}

/// Closest scenario name to `name` — the CLI's "did you mean" hint.
/// Containment (a typed prefix/fragment of ≥ 3 chars) wins; otherwise a
/// small edit distance. `None` when nothing is plausibly close.
pub fn suggest(name: &str) -> Option<&'static str> {
    let n = name.to_ascii_lowercase();
    if n.is_empty() {
        return None;
    }
    if n.len() >= 3 {
        // Prefix beats containment beats edit distance: `solar` should
        // point at `solar-battery`, not at whichever name drifts closest.
        if let Some(c) = SCENARIO_NAMES.iter().copied().find(|c| c.starts_with(n.as_str())) {
            return Some(c);
        }
        if let Some(c) =
            SCENARIO_NAMES.iter().copied().find(|c| c.contains(n.as_str()) || n.contains(c))
        {
            return Some(c);
        }
    }
    let (d, best) = SCENARIO_NAMES
        .iter()
        .copied()
        .map(|c| (levenshtein(&n, c), c))
        .min_by_key(|&(d, _)| d)?;
    (d <= 2 + best.len() / 4).then_some(best)
}

/// Plain Levenshtein edit distance (two-row DP).
fn levenshtein(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

fn static_traces(specs: &[NodeSpec]) -> Vec<IntensityTrace> {
    specs.iter().map(|s| IntensityTrace::Static(s.intensity)).collect()
}

fn paper_3_node(requests: usize, seed: u64) -> Scenario {
    let specs = NodeSpec::paper_nodes();
    Scenario {
        name: "paper-3-node".into(),
        traces: static_traces(&specs),
        capacity: vec![1; specs.len()],
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz: 6.0 },
        requests,
        churn: Vec::new(),
        microgrids: Vec::new(),
        sites: None,
        config: SimConfig { seed, ..SimConfig::default() },
    }
}

fn fleet_n(n: usize, requests: usize, seed: u64) -> Scenario {
    let config = SimConfig { seed, ..SimConfig::default() };
    let specs = fleet::synth_fleet(n, seed);
    let capacity = fleet::capacities(&specs);
    let rate_hz = 0.6 * fleet::service_capacity_hz(&specs, &capacity, config.base_exec_ms);
    Scenario {
        name: "fleet-100".into(),
        traces: static_traces(&specs),
        capacity,
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz },
        requests,
        churn: Vec::new(),
        microgrids: Vec::new(),
        sites: None,
        config,
    }
}

/// Virtual horizon the diurnal scenario spreads its arrivals over: the
/// first quarter of the day curve, where solar-driven intensity moves
/// monotonically away from the nightly mean.
pub const DIURNAL_HORIZON_S: f64 = 21_600.0;

fn diurnal_solar(n: usize, requests: usize, seed: u64) -> Scenario {
    let config = SimConfig { seed, ..SimConfig::default() };
    let specs = fleet::synth_fleet(n, seed);
    let traces = specs
        .iter()
        .map(|s| IntensityTrace::Diurnal {
            mean: s.intensity,
            amplitude: 0.4 * s.intensity,
            period_s: 86_400.0,
            phase_s: 0.0,
        })
        .collect();
    let capacity = fleet::capacities(&specs);
    Scenario {
        name: "diurnal-solar".into(),
        traces,
        capacity,
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz: requests as f64 / DIURNAL_HORIZON_S },
        requests,
        churn: Vec::new(),
        microgrids: Vec::new(),
        sites: None,
        config,
    }
}

fn bursty(nodes: usize, requests: usize, seed: u64) -> Scenario {
    let config = SimConfig { seed, ..SimConfig::default() };
    let paper = nodes == 0 || nodes == 3;
    let specs = if paper { NodeSpec::paper_nodes() } else { fleet::synth_fleet(nodes, seed) };
    let capacity = if paper { vec![1; specs.len()] } else { fleet::capacities(&specs) };
    let cap_hz = fleet::service_capacity_hz(&specs, &capacity, config.base_exec_ms);
    Scenario {
        name: "bursty".into(),
        traces: static_traces(&specs),
        capacity,
        specs,
        arrivals: ArrivalProcess::Mmpp {
            rate_low_hz: 0.25 * cap_hz,
            rate_high_hz: 1.5 * cap_hz,
            mean_dwell_s: 20.0,
        },
        requests,
        churn: Vec::new(),
        microgrids: Vec::new(),
        sites: None,
        config,
    }
}

fn churn(n: usize, requests: usize, seed: u64) -> Scenario {
    // lint: allow(P2 one-shot scenario-builder guard)
    assert!(n >= 3, "churn scenario needs at least 3 nodes");
    let config = SimConfig { seed, ..SimConfig::default() };
    let specs = fleet::synth_fleet(n, seed);
    let capacity = fleet::capacities(&specs);
    let rate_hz = 0.5 * fleet::service_capacity_hz(&specs, &capacity, config.base_exec_ms);
    let horizon_s = requests as f64 / rate_hz;
    // Node n-1 is dead from the start (must never receive work); the first
    // third of the fleet departs at 30% of the horizon and rejoins at 70%.
    let mut churn = vec![ChurnEvent { at_s: 0.0, node: n - 1, up: false }];
    for i in 0..(n / 3).max(1) {
        churn.push(ChurnEvent { at_s: 0.3 * horizon_s, node: i, up: false });
        churn.push(ChurnEvent { at_s: 0.7 * horizon_s, node: i, up: true });
    }
    Scenario {
        name: "churn".into(),
        traces: static_traces(&specs),
        capacity,
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz },
        requests,
        churn,
        microgrids: Vec::new(),
        sites: None,
        config,
    }
}

/// Virtual window the `real-trace` scenario spreads its arrivals over: the
/// first half of the day curve, so morning-peak arrivals have a midday
/// solar trough inside their deferral slack.
pub const REAL_TRACE_ARRIVAL_WINDOW_S: f64 = 43_200.0;

/// Deferral slack the `real-trace` scenario grants every arrival (6 h).
pub const REAL_TRACE_SLACK_S: f64 = 21_600.0;

/// Build the `real-trace` scenario from any ElectricityMaps-style CSV (the
/// bundled day by default; `carbonedge sim --trace-csv` feeds a real
/// export through here). One node per zone when `nodes == 0`, otherwise
/// `nodes` nodes cycling the zones. Paper-node chassis carry the traces;
/// each spec's static `intensity` is set to its zone's day-mean so
/// cold-start scores stay coherent with the grid it actually runs on.
pub fn real_trace_from_csv(
    csv: &str,
    nodes: usize,
    requests: usize,
    seed: u64,
) -> Result<Scenario, String> {
    let zones = zone_traces_from_csv(csv)?;
    let requests = if requests == 0 { 20_000 } else { requests };
    let n = if nodes == 0 { zones.len() } else { nodes };
    let chassis = NodeSpec::paper_nodes();
    let mut specs = Vec::with_capacity(n);
    let mut traces = Vec::with_capacity(n);
    for i in 0..n {
        let (zone, trace) = &zones[i % zones.len()];
        let mut spec = chassis[i % chassis.len()].clone();
        spec.name = format!("edge-{zone}-{i:02}");
        spec.intensity = trace.mean(86_400.0, 288);
        specs.push(spec);
        traces.push(trace.clone());
    }
    Ok(Scenario {
        name: "real-trace".into(),
        traces,
        capacity: vec![2; n],
        specs,
        arrivals: ArrivalProcess::Poisson {
            rate_hz: requests as f64 / REAL_TRACE_ARRIVAL_WINDOW_S,
        },
        requests,
        churn: Vec::new(),
        microgrids: Vec::new(),
        sites: None,
        config: SimConfig {
            seed,
            deferral: Some(DeferralSpec {
                slack_s: REAL_TRACE_SLACK_S,
                headroom_s: 900.0,
                policy: crate::carbon::DeferralPolicy::default(),
            }),
            ..SimConfig::default()
        },
    })
}

/// Mean real-executor time per request in the `deferral-routing` scenario
/// (ms): ≈ 1 s of service per task on the paper chassis, so the clean
/// zone genuinely contends and routing spills are common — the regime
/// where deciding *where* and *when* jointly beats route-then-defer.
pub const DEFERRAL_ROUTING_BASE_EXEC_MS: f64 = 48.0;

/// The joint defer+route showcase: the `real-trace` zone fleet with one
/// service slot per node and ~1 s tasks. Under route-then-defer, parked
/// work stampedes the cleanest zone at its trough (the whole backlog
/// targets the single argmin slot), saturates it past the load cutoff and
/// spills onto dirty grids — at high request counts it even rejects a
/// large share outright. [`crate::scheduler::DeferAwareGreenScheduler`]
/// decides jointly over every node's blended forecast and spreads
/// releases across the trough plateau, absorbing the same workload
/// cleanly ([`crate::experiments::sim_deferral_routing_comparison`] is
/// the A/B).
fn deferral_routing(nodes: usize, requests: usize, seed: u64) -> Scenario {
    let mut sc = real_trace_from_csv(BUNDLED_GRID_DAY_CSV, nodes, requests, seed)
        .expect("bundled grid-day CSV is valid"); // lint: allow(P1 compile-time data)
    sc.name = "deferral-routing".into();
    sc.capacity = vec![1; sc.specs.len()];
    sc.config.base_exec_ms = DEFERRAL_ROUTING_BASE_EXEC_MS;
    sc
}

/// Fixed reference fleet size whose service capacity the `consolidation`
/// arrival rate is derived from — so the *same* workload can be replayed
/// against any fleet size and only the number of idle floors changes.
pub const CONSOLIDATION_REF_NODES: usize = 3;

fn consolidation(n: usize, requests: usize, seed: u64) -> Scenario {
    let config = SimConfig { seed, ..SimConfig::default() };
    // Identical hosts (same grid, same chassis) so the only thing a bigger
    // fleet adds is idle floors. Power split comes from the calibrated
    // HostPowerModel: ≈142 W flat out, ≈54 W doing nothing.
    let (rated_power_w, idle_w) = crate::config::default_host_power().node_power_split();
    let specs: Vec<NodeSpec> = (0..n)
        .map(|i| NodeSpec {
            name: format!("edge-{i:03}"),
            cpu_quota: 1.0,
            mem_mb: 1024,
            intensity: 475.0, // global-average grid
            rated_power_w,
            idle_w,
            prior_ms: 250.0,
            alpha: 0.005,
            overhead_ms: 8.0,
            time_scale: 20.6,
            adaptive: false,
            batch_gamma: 0.8,
            batch_beta: 0.2,
        })
        .collect();
    let capacity = vec![1; n];
    // Load worth ~65% of a 3-node reference fleet, independent of `n` —
    // all hosts are identical, so one node's capacity times the reference
    // count gives the same workload at every fleet size (including n < 3).
    let per_node_hz = fleet::service_capacity_hz(&specs[..1], &capacity[..1], config.base_exec_ms);
    let rate_hz = 0.65 * CONSOLIDATION_REF_NODES as f64 * per_node_hz;
    Scenario {
        name: "consolidation".into(),
        traces: static_traces(&specs),
        capacity,
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz },
        requests,
        churn: Vec::new(),
        microgrids: Vec::new(),
        sites: None,
        config,
    }
}

/// Virtual horizon the `solar-battery` scenario spreads its arrivals over:
/// one full day, so the PV window, the battery bridge and the grid-only
/// pre-dawn hours all sit inside the run.
pub const SOLAR_BATTERY_HORIZON_S: f64 = 86_400.0;

/// `solar-battery` microgrid sizing: a 400 W-peak half-sine PV array and a
/// 600 Wh 1C battery starting overnight-depleted at 30% SoC, 90%
/// round-trip efficient. Against the ≈54 W idle floor this covers daytime
/// draw from the sun, bridges the evening from storage, and leaves only
/// the pre-dawn hours on the grid.
pub const SOLAR_BATTERY_PV_PEAK_W: f64 = 400.0;
pub const SOLAR_BATTERY_WH: f64 = 600.0;

fn solar_battery(n: usize, requests: usize, seed: u64) -> Scenario {
    let config = SimConfig { seed, ..SimConfig::default() };
    // Identical idle-capable hosts (the consolidation chassis) on the
    // global-average grid: the only carbon lever is the local supply side.
    let (rated_power_w, idle_w) = crate::config::default_host_power().node_power_split();
    let specs: Vec<NodeSpec> = (0..n)
        .map(|i| NodeSpec {
            name: format!("solar-edge-{i:02}"),
            cpu_quota: 1.0,
            mem_mb: 1024,
            intensity: 475.0,
            rated_power_w,
            idle_w,
            prior_ms: 250.0,
            alpha: 0.005,
            overhead_ms: 8.0,
            time_scale: 20.6,
            adaptive: false,
            batch_gamma: 0.8,
            batch_beta: 0.2,
        })
        .collect();
    let microgrids = (0..n)
        .map(|_| Some(MicrogridSpec::solar(SOLAR_BATTERY_PV_PEAK_W, SOLAR_BATTERY_WH, 0.9, 0.3)))
        .collect();
    Scenario {
        name: "solar-battery".into(),
        traces: static_traces(&specs),
        capacity: vec![1; n],
        specs,
        arrivals: ArrivalProcess::Poisson {
            rate_hz: requests as f64 / SOLAR_BATTERY_HORIZON_S,
        },
        requests,
        churn: Vec::new(),
        microgrids,
        sites: None,
        config,
    }
}

fn microgrid_fleet(n: usize, requests: usize, seed: u64) -> Scenario {
    let config = SimConfig { seed, ..SimConfig::default() };
    let specs = fleet::synth_fleet(n, seed);
    let capacity = fleet::capacities(&specs);
    // 40% of fleet capacity: the microgrid half of the fleet can absorb
    // most of the load without saturating, so carbon-aware routing has
    // real freedom to follow the charge.
    let rate_hz = 0.4 * fleet::service_capacity_hz(&specs, &capacity, config.base_exec_ms);
    // Every even-indexed node gets a microgrid: PV sized at 3× the node's
    // rated draw with sunrises staggered across "longitudes", plus a 1C
    // battery (3 Wh per rated watt) starting well charged at 90% — the
    // charged/sunlit half of the fleet reads as near-zero effective
    // intensity while its storage lasts.
    let microgrids = specs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            (i % 2 == 0).then(|| MicrogridSpec {
                pv: PvProfile::diurnal_with_sunrise(3.0 * s.rated_power_w, i as f64 * 1_800.0),
                battery: BatterySpec::simple(3.0 * s.rated_power_w, 0.9, 0.9),
                charge: ChargePolicy::Off,
                discharge: DischargePolicy::Greedy,
            })
        })
        .collect();
    Scenario {
        name: "microgrid-fleet".into(),
        traces: static_traces(&specs),
        capacity,
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz },
        requests,
        churn: Vec::new(),
        microgrids,
        sites: None,
        config,
    }
}

/// Request rate the `arbitrage` scenario is pinned to (Hz): 4000 requests
/// per virtual day, **independent of the request count** (which only sets
/// the run length) — like `consolidation`'s pinned rate, this keeps the
/// battery dispatch timing the A/B probes invariant under `--requests`.
pub const ARBITRAGE_RATE_HZ: f64 = 4_000.0 / 86_400.0;

/// `arbitrage` storage sizing: a 300 Wh battery charging at 1C but
/// discharging through a 120 W inverter — enough to carry one node's task
/// draw, not the whole fleet's, so the marginal price genuinely blends.
pub const ARBITRAGE_BATTERY_WH: f64 = 300.0;
pub const ARBITRAGE_DISCHARGE_W: f64 = 120.0;

/// Deferral slack the `arbitrage` scenario grants every arrival (4 h).
pub const ARBITRAGE_SLACK_S: f64 = 14_400.0;

/// Mean real-executor time per request in the `arbitrage` scenario (ms):
/// ≈ 10 s of service per task, so task carbon is large enough for defer
/// verdicts to show up in the totals.
pub const ARBITRAGE_BASE_EXEC_MS: f64 = 480.0;

/// One day of the `arbitrage` duck curve, hourly (gCO₂/kWh): a cheap
/// clean night (wind), a morning ramp, a solar belly, a dirty evening
/// peak, a post-peak shoulder and a late decline.
const ARBITRAGE_DUCK_DAY_G: [f64; 24] = [
    150.0, 145.0, 140.0, 140.0, 145.0, 160.0, // clean night
    380.0, 480.0, 520.0, // morning ramp
    430.0, 330.0, 260.0, 230.0, 225.0, 240.0, 300.0, // solar belly
    520.0, 640.0, 680.0, 660.0, // evening peak
    560.0, 540.0, // shoulder
    300.0, 200.0, // decline into the next night
];

/// The duck curve tiled over `days` days (hourly step-held samples).
fn arbitrage_duck_trace(days: usize) -> IntensityTrace {
    let mut pts = Vec::with_capacity(days * 24);
    for d in 0..days {
        for (h, &v) in ARBITRAGE_DUCK_DAY_G.iter().enumerate() {
            pts.push((d as f64 * 86_400.0 + h as f64 * 3_600.0, v));
        }
    }
    // lint: allow(P1 static duck-curve table, strictly increasing timestamps)
    IntensityTrace::from_samples(pts).expect("duck curve samples are valid")
}

/// The grid-charge arbitrage showcase: an idle-free fleet (every gram is
/// task-attributed, isolating the deferral economics) on a duck-curve
/// grid, each node behind a grid-chargeable battery
/// ([`ChargePolicy::threshold`]: import during the cleanest quarter of
/// the day-ahead window) with an inverter-limited discharge rate, and
/// 4 h of deferral slack on every arrival. The battery fills overnight at
/// ~150 g/kWh (carried at its embodied ~150/η intensity by the
/// stored-carbon ledger) and dies partway through the dirty evening —
/// exactly the regime where charge-frozen forecasts defer work onto
/// batteries that will be empty by the release slot, and where the
/// SoC-trajectory forecasts ([`crate::microgrid::Microgrid::project`])
/// price release slots truthfully
/// ([`crate::experiments::sim_arbitrage_comparison`] is the A/B).
fn arbitrage(n: usize, requests: usize, seed: u64) -> Scenario {
    let config = SimConfig {
        seed,
        base_exec_ms: ARBITRAGE_BASE_EXEC_MS,
        deferral: Some(DeferralSpec {
            slack_s: ARBITRAGE_SLACK_S,
            headroom_s: 900.0,
            policy: crate::carbon::DeferralPolicy::default(),
        }),
        ..SimConfig::default()
    };
    // Tile enough duck days to cover the pinned-rate run plus slack; the
    // charge policy additionally peeks one window past the horizon.
    let horizon_s = requests as f64 / ARBITRAGE_RATE_HZ + ARBITRAGE_SLACK_S;
    let days = (horizon_s / 86_400.0).ceil() as usize + 2;
    let trace = arbitrage_duck_trace(days);
    let day_mean = trace.mean(86_400.0, 288);
    // Idle-free host chassis (the Table II calibration convention): rated
    // draw from the calibrated host model, every watt task-attributed.
    let (rated_power_w, _) = crate::config::default_host_power().node_power_split();
    let specs: Vec<NodeSpec> = (0..n)
        .map(|i| NodeSpec {
            name: format!("arb-{i:02}"),
            cpu_quota: 1.0,
            mem_mb: 1024,
            intensity: day_mean,
            rated_power_w,
            idle_w: 0.0,
            prior_ms: 250.0,
            alpha: 0.005,
            overhead_ms: 8.0,
            time_scale: 20.6,
            adaptive: false,
            batch_gamma: 0.8,
            batch_beta: 0.2,
        })
        .collect();
    let microgrids = (0..n)
        .map(|_| {
            Some(MicrogridSpec {
                pv: PvProfile::none(),
                battery: BatterySpec {
                    capacity_wh: ARBITRAGE_BATTERY_WH,
                    max_charge_w: ARBITRAGE_BATTERY_WH, // 1C charger
                    max_discharge_w: ARBITRAGE_DISCHARGE_W,
                    rt_efficiency: 0.9,
                    initial_soc: 0.3,
                },
                charge: ChargePolicy::threshold(crate::microgrid::DEFAULT_CHARGE_PERCENTILE),
                discharge: DischargePolicy::Greedy,
            })
        })
        .collect();
    Scenario {
        name: "arbitrage".into(),
        traces: vec![trace; n],
        capacity: vec![1; n],
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz: ARBITRAGE_RATE_HZ },
        requests,
        churn: Vec::new(),
        microgrids,
        sites: None,
        config,
    }
}

/// `batch-serving` batch formation: a 200 ms window and a fill target of
/// 8 — interactive-tier friendly (the window is small next to a 3 s SLO)
/// while wide enough for the `b^0.8` latency curve to pay.
pub const BATCH_SERVING_WINDOW_MS: f64 = 200.0;
pub const BATCH_SERVING_MAX_BATCH: usize = 8;

/// `batch-serving` arrival pressure: 1.3× the fleet's *one-per-slot*
/// service capacity. Unbatched service saturates and queues grow for the
/// whole run; a fill of 8 at γ = 0.8 serves ≈ 8/8^0.8 ≈ 1.5× per slot,
/// so the batched fleet runs the same load at ~85% utilization.
pub const BATCH_SERVING_OVERLOAD: f64 = 1.3;

/// `batch-serving` hot-model weight: ≈ 1 s single-task service on the
/// consolidation chassis (48 × 20.6 + 8 ms overhead), so the 200 ms
/// formation window is a small fraction of one inference and the batch
/// throughput multiplier — not formation latency — dominates sojourn
/// time.
pub const BATCH_SERVING_BASE_EXEC_MS: f64 = 48.0;

/// The `batch-serving` tenant mix: **one hot model** behind three
/// deadline tiers. Every class runs the same weights (`exec_scale`
/// 1.0 — the arrival-rate calibration against `base_exec_ms` stays
/// honest); what differs is the SLO budget and the traffic share.
/// Dispatch priorities are deliberately *equal*: under sustained
/// overload a strict priority order (no aging) starves the lowest
/// tier into the fleet's p99, so seals go oldest-head-first and the
/// SLO tiers carry the differentiation (`multi-tenant` exercises the
/// priority spread).
pub fn batch_serving_mix() -> WorkloadMix {
    let class = |name: &str, slo_s: f64, weight: f64| WorkloadClass {
        name: name.into(),
        demand: TaskDemand::default(),
        slo_s,
        exec_scale: 1.0,
        priority: 0,
        weight,
    };
    WorkloadMix {
        classes: vec![
            class("interactive", 3.0, 3.0),
            class("standard", 10.0, 2.0),
            class("background", 60.0, 1.0),
        ],
    }
}

/// The batched-serving showcase: identical idle-capable hosts with one
/// service slot each under the three-tier mix at
/// [`BATCH_SERVING_OVERLOAD`]× one-per-slot capacity. The batched fleet
/// absorbs it; the [`batching_disabled_twin`] drowns — the A/B
/// [`crate::experiments::sim_batching_comparison`] measures the
/// gCO₂/req and p99 gap.
fn batch_serving(n: usize, requests: usize, seed: u64) -> Scenario {
    let config = SimConfig {
        seed,
        base_exec_ms: BATCH_SERVING_BASE_EXEC_MS,
        workload: Some(batch_serving_mix()),
        batching: Some(BatchSpec {
            window_ms: BATCH_SERVING_WINDOW_MS,
            max_batch: BATCH_SERVING_MAX_BATCH,
        }),
        ..SimConfig::default()
    };
    // A dedicated accelerator host pinned to the hot model: high idle
    // floor (an idling inference server draws most of its peak — the
    // floor is exactly what batch consolidation amortizes), modest
    // incremental draw per busy slot, on the global-average grid.
    let specs: Vec<NodeSpec> = (0..n)
        .map(|i| NodeSpec {
            name: format!("serve-{i:02}"),
            cpu_quota: 1.0,
            mem_mb: 2048,
            intensity: 475.0,
            rated_power_w: 160.0,
            idle_w: 100.0,
            prior_ms: 250.0,
            alpha: 0.005,
            overhead_ms: 8.0,
            time_scale: 20.6,
            adaptive: false,
            batch_gamma: 0.8,
            batch_beta: 0.2,
        })
        .collect();
    let capacity = vec![1; n];
    let rate_hz =
        BATCH_SERVING_OVERLOAD * fleet::service_capacity_hz(&specs, &capacity, config.base_exec_ms);
    Scenario {
        name: "batch-serving".into(),
        traces: static_traces(&specs),
        capacity,
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz },
        requests,
        churn: Vec::new(),
        microgrids: Vec::new(),
        sites: None,
        config,
    }
}

/// The `multi-tenant` mix: three tenants with genuinely different models
/// (a distilled vision model, an embedding service, a hefty generator),
/// demands small enough to fit every `REGIONS` chassis (min 512 MB /
/// 0.4 cores), and an SLO/priority spread from 2 s interactive down to
/// best-effort batch.
pub fn multi_tenant_mix() -> WorkloadMix {
    let class = |name: &str,
                 cpu: f64,
                 mem_mb: usize,
                 slo_s: f64,
                 exec_scale: f64,
                 priority: u8,
                 weight: f64| WorkloadClass {
        name: name.into(),
        demand: TaskDemand { cpu, mem_mb, ..TaskDemand::default() },
        slo_s,
        exec_scale,
        priority,
        weight,
    };
    WorkloadMix {
        classes: vec![
            class("vision-small", 0.1, 128, 2.0, 0.5, 2, 3.0),
            class("embed", 0.2, 256, 8.0, 1.0, 1, 2.0),
            class("generate", 0.3, 384, f64::INFINITY, 3.0, 0, 1.0),
        ],
    }
}

/// The heterogeneous multi-tenant showcase: the `REGIONS` fleet with
/// microgrids on its even-indexed half, three tenants batching through
/// per-`(node, class)` queues (window 100 ms, fill 4), and
/// [`SimConfig::demand_aware_projections`] on — SoC trajectories price
/// release slots against the backlog that will drain through the
/// battery, not just the work already in service.
fn multi_tenant(n: usize, requests: usize, seed: u64) -> Scenario {
    let config = SimConfig {
        seed,
        workload: Some(multi_tenant_mix()),
        batching: Some(BatchSpec { window_ms: 100.0, max_batch: 4 }),
        demand_aware_projections: true,
        ..SimConfig::default()
    };
    let specs = fleet::synth_fleet(n, seed);
    let capacity = fleet::capacities(&specs);
    // Weighted mean exec_scale is (3·0.5 + 2·1.0 + 1·3.0)/6 ≈ 1.08; 55%
    // of nominal capacity leaves the mix comfortably schedulable while
    // queues still form often enough for batching to matter.
    let rate_hz = 0.55 * fleet::service_capacity_hz(&specs, &capacity, config.base_exec_ms);
    let microgrids = specs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            (i % 2 == 0).then(|| MicrogridSpec {
                pv: PvProfile::diurnal_with_sunrise(3.0 * s.rated_power_w, i as f64 * 1_800.0),
                battery: BatterySpec::simple(3.0 * s.rated_power_w, 0.9, 0.9),
                charge: ChargePolicy::Off,
                discharge: DischargePolicy::Greedy,
            })
        })
        .collect();
    Scenario {
        name: "multi-tenant".into(),
        traces: static_traces(&specs),
        capacity,
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz },
        requests,
        churn: Vec::new(),
        microgrids,
        sites: None,
        config,
    }
}

/// Twin of `sc` with batch formation switched off (`batching: None`) —
/// the one-task-per-slot baseline the batching margin is measured
/// against ([`crate::experiments::sim_batching_comparison`]). The
/// workload mix stays: the twin serves the same classes, SLOs and model
/// scales, just one task per service slot.
pub fn batching_disabled_twin(sc: &Scenario) -> Scenario {
    let mut twin = sc.clone();
    twin.name = format!("{}-unbatched", sc.name);
    twin.config.batching = None;
    twin
}

/// Twin of `sc` with grid charging switched off on every microgrid
/// (PV-excess charging stays) — the baseline the arbitrage margin is
/// measured against.
pub fn charge_disabled_twin(sc: &Scenario) -> Scenario {
    let mut twin = sc.clone();
    twin.name = format!("{}-no-charge", sc.name);
    for mg in twin.microgrids.iter_mut().flatten() {
        mg.charge = ChargePolicy::Off;
    }
    twin
}

/// Twin of `sc` with the legacy charge-frozen forecasts restored
/// (`SimConfig::charge_frozen_forecasts`) — the baseline the
/// SoC-trajectory forecasting margin is measured against.
pub fn charge_frozen_twin(sc: &Scenario) -> Scenario {
    let mut twin = sc.clone();
    twin.name = format!("{}-frozen", sc.name);
    twin.config.charge_frozen_forecasts = true;
    twin
}

/// Grid-only twin of `sc`: same fleet, arrivals and seed with every
/// microgrid removed — the baseline a supply-side split is measured
/// against ([`crate::experiments::sim_microgrid_comparison`]).
pub fn microgrid_disabled_twin(sc: &Scenario) -> Scenario {
    let mut twin = sc.clone();
    twin.name = format!("{}-no-mg", sc.name);
    twin.microgrids = Vec::new();
    twin
}

/// Single-node monolithic baseline for `sc`: the same arrival process and
/// request budget against one host-class node — full-load host power at the
/// host grid scenario (Config::default's 530 gCO₂/kWh), the paper's
/// "Monolithic" row transplanted into virtual time.
pub fn monolithic_of(sc: &Scenario) -> Scenario {
    let host_w = crate::config::default_host_power().power_watts(1.0, 1.0);
    let spec = NodeSpec {
        name: "host-mono".into(),
        cpu_quota: 1.0,
        mem_mb: 4096,
        intensity: 530.0,
        rated_power_w: host_w,
        // Idle-free like the paper nodes: the monolithic row is the Table II
        // calibration anchor, where all power is task-attributed.
        idle_w: 0.0,
        prior_ms: 250.0,
        alpha: 0.0,
        overhead_ms: 0.0,
        time_scale: 20.6,
        adaptive: false,
        batch_gamma: 0.8,
        batch_beta: 0.2,
    };
    Scenario {
        name: format!("{}-monolithic", sc.name),
        traces: vec![IntensityTrace::Static(spec.intensity)],
        capacity: vec![1],
        specs: vec![spec],
        arrivals: sc.arrivals.clone(),
        requests: sc.requests,
        churn: Vec::new(),
        microgrids: Vec::new(),
        sites: None,
        config: sc.config.clone(),
    }
}

/// The three regions of the geographic scenarios: name and timezone
/// offset (seconds east of the first region). 8 h apart, so the grid
/// troughs — and, in `follow-the-sun`, the PV windows — rotate around
/// the clock and together cover the whole day.
pub const MULTI_SITE_REGIONS: [(&str, f64); 3] =
    [("eu-west", 0.0), ("us-west", 28_800.0), ("ap-east", 57_600.0)];

/// Virtual horizon the geographic scenarios spread arrivals over: one
/// full day, so every region sees its entire diurnal grid cycle.
pub const MULTI_SITE_HORIZON_S: f64 = 86_400.0;

/// One-way WAN latency between any two regions (ms): long-haul
/// inter-continental distance, charged to every shipped request's
/// end-to-end latency.
pub const MULTI_SITE_WAN_LATENCY_MS: f64 = 60.0;

/// Diurnal swing of each regional grid around the 475 g global mean —
/// ±45%, so regional troughs are genuinely worth a WAN hop.
pub const MULTI_SITE_GRID_SWING_G: f64 = 215.0;

/// `follow-the-sun` deadline slack (s): tight enough that deferring in
/// place cannot ride out a timezone (the sun moves 8 h between regions),
/// so *where* has to do the work that *when* cannot.
pub const FOLLOW_SUN_SLACK_S: f64 = 1_800.0;

/// PV peak per `follow-the-sun` node, as a multiple of its rated draw —
/// generous headroom so a sunlit region serves at ~zero marginal
/// intensity even near its sunrise/sunset shoulders.
pub const FOLLOW_SUN_PV_PEAK_X: f64 = 3.0;

/// Region roster for the geographic scenarios: `k` timezones spread
/// uniformly over the day, so the follow-the-sun property (some region
/// always near its grid trough / under its sun) survives any count. The
/// default three keep their [`MULTI_SITE_REGIONS`] names; other counts
/// get synthetic `region-NN` entries. `sim --sites N` lands here.
fn geo_regions(k: usize) -> Vec<(String, f64)> {
    (0..k)
        .map(|i| {
            let tz = MULTI_SITE_HORIZON_S * i as f64 / k as f64;
            let name = if k == MULTI_SITE_REGIONS.len() {
                MULTI_SITE_REGIONS[i].0.to_string()
            } else {
                format!("region-{i:02}")
            };
            (name, tz)
        })
        .collect()
}

/// Round-robin [`SiteLayer`] over a region roster with a uniform WAN
/// mesh priced per [`DEFAULT_REQUEST_BYTES`]-sized request.
fn site_layer(n: usize, regions: &[(String, f64)], router: RouterSpec) -> SiteLayer {
    let k = regions.len();
    SiteLayer {
        sites: regions.iter().map(|(name, tz)| SiteSpec::new(name, *tz)).collect(),
        site_of: (0..n).map(|i| i % k).collect(),
        topology: SiteTopology::uniform(
            k,
            WanLink::of_bytes(
                MULTI_SITE_WAN_LATENCY_MS,
                DEFAULT_REQUEST_BYTES,
                DEFAULT_WAN_J_PER_BYTE,
            ),
        ),
        router,
    }
}

/// Identical idle-free hosts for the geographic scenarios, named after
/// their region. Idle-free because all three regions stay online around
/// the clock under every router — the floors would be a constant every
/// variant pays identically, and zeroing them makes gCO₂/req purely a
/// function of placement and WAN transfer.
fn geo_fleet(n: usize, regions: &[(String, f64)]) -> Vec<NodeSpec> {
    let (rated_power_w, _) = crate::config::default_host_power().node_power_split();
    (0..n)
        .map(|i| NodeSpec {
            name: format!("{}-{:02}", regions[i % regions.len()].0, i),
            cpu_quota: 1.0,
            mem_mb: 1024,
            intensity: 475.0,
            rated_power_w,
            idle_w: 0.0,
            prior_ms: 250.0,
            alpha: 0.005,
            overhead_ms: 8.0,
            time_scale: 20.6,
            adaptive: false,
            batch_gamma: 0.8,
            batch_beta: 0.2,
        })
        .collect()
}

/// Three-region staggered-grid fleet: identical idle-free hosts split
/// round-robin across [`MULTI_SITE_REGIONS`], each region on the same
/// diurnal grid shifted by its timezone offset, WAN links priced into
/// both the latency and the carbon ledgers, and the deadline-feasible
/// carbon router in front ([`RouterSpec::default`]). At any instant some
/// region sits near its grid trough, so cross-site shipping has standing
/// material gain over serving at home
/// ([`crate::experiments::sim_router_comparison`] is the A/B/C).
fn multi_site(n: usize, requests: usize, seed: u64) -> Scenario {
    multi_site_over(&geo_regions(MULTI_SITE_REGIONS.len()), n, requests, seed)
}

/// [`multi_site`] over an explicit region roster (`sim --sites N`).
fn multi_site_over(
    regions: &[(String, f64)],
    n: usize,
    requests: usize,
    seed: u64,
) -> Scenario {
    let config = SimConfig { seed, ..SimConfig::default() };
    let layer = site_layer(n, regions, RouterSpec::default());
    let specs = geo_fleet(n, regions);
    let traces = specs
        .iter()
        .enumerate()
        .map(|(i, _)| IntensityTrace::Diurnal {
            mean: 475.0,
            amplitude: MULTI_SITE_GRID_SWING_G,
            period_s: 86_400.0,
            phase_s: layer.sites[layer.site_of[i]].tz_offset_s,
        })
        .collect();
    Scenario {
        name: "multi-site".into(),
        traces,
        capacity: vec![1; n],
        specs,
        arrivals: ArrivalProcess::Poisson { rate_hz: requests as f64 / MULTI_SITE_HORIZON_S },
        requests,
        churn: Vec::new(),
        microgrids: Vec::new(),
        sites: Some(layer),
        config,
    }
}

/// The follow-the-sun showcase: the `multi-site` fleet with a 3×-rated
/// PV array behind every node, sunrise staggered by region timezone, and
/// 30 min of deadline slack. Each region's 12 h PV window covers a third
/// of the day offset by 8 h, so their union covers *all* of it: a single
/// region in green mode serves at ~zero marginal intensity only while
/// its own sun is up, while the cross-site deadline router always has
/// some sunlit region within one WAN hop. Fleet gCO₂/req under the
/// router beats the best single-site twin ([`single_site_twin`]) by well
/// over the 0.9× acceptance margin.
fn follow_the_sun(n: usize, requests: usize, seed: u64) -> Scenario {
    solarize(multi_site(n, requests, seed))
}

/// The follow-the-sun mutation over any `multi-site`-shaped scenario:
/// staggered PV arrays + tight deadline slack (see [`follow_the_sun`]).
fn solarize(mut sc: Scenario) -> Scenario {
    sc.name = "follow-the-sun".into();
    // lint: allow(P1 solarize is only applied to multi-site-shaped scenarios)
    let layer = sc.sites.as_ref().expect("multi-site always has a site layer");
    sc.microgrids = sc
        .specs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let sunrise = 21_600.0 + layer.sites[layer.site_of[i]].tz_offset_s;
            Some(MicrogridSpec {
                pv: PvProfile::diurnal_with_sunrise(
                    FOLLOW_SUN_PV_PEAK_X * s.rated_power_w,
                    sunrise,
                ),
                battery: BatterySpec::none(),
                charge: ChargePolicy::Off,
                discharge: DischargePolicy::Greedy,
            })
        })
        .collect();
    sc.config.deferral = Some(DeferralSpec {
        slack_s: FOLLOW_SUN_SLACK_S,
        headroom_s: 300.0,
        policy: crate::carbon::DeferralPolicy::default(),
    });
    sc
}

/// Rebuild a geographic scenario over `k` regions instead of the default
/// three (`sim --sites N`): timezones spread uniformly over the day,
/// nodes split round-robin, defaulting to three nodes per region. `None`
/// for a non-geographic scenario name or `k < 2` (a site layer needs
/// peers to ship to).
pub fn with_site_count(
    name: &str,
    k: usize,
    nodes: usize,
    requests: usize,
    seed: u64,
) -> Option<Scenario> {
    if k < 2 {
        return None;
    }
    let regions = geo_regions(k);
    let n = if nodes == 0 { 3 * k } else { nodes };
    let requests = if requests == 0 { 20_000 } else { requests };
    match name {
        "multi-site" => Some(multi_site_over(&regions, n, requests, seed)),
        "follow-the-sun" => Some(solarize(multi_site_over(&regions, n, requests, seed))),
        _ => None,
    }
}

/// Single-region twin of a geographic scenario: one site's nodes, traces
/// and microgrids carved out as a flat fleet (no site layer, no router)
/// that still faces the *same* arrival process and request budget — the
/// whole planet's demand forced through one region. The best of these
/// twins over all sites is the "best single-site green mode" baseline the
/// follow-the-sun margin is measured against.
pub fn single_site_twin(sc: &Scenario, site: usize) -> Scenario {
    // lint: allow(P1 documented precondition of the twin-builder API)
    let layer = sc.sites.as_ref().expect("single_site_twin needs a geographic scenario");
    // lint: allow(P2 one-shot twin-builder guard)
    assert!(site < layer.sites.len(), "site {site} out of range");
    let keep: Vec<usize> = (0..sc.specs.len()).filter(|&i| layer.site_of[i] == site).collect();
    // lint: allow(P2 one-shot twin-builder guard)
    assert!(!keep.is_empty(), "site {site} has no nodes");
    let pos: std::collections::BTreeMap<usize, usize> =
        keep.iter().enumerate().map(|(p, &g)| (g, p)).collect();
    let mut twin = sc.clone();
    twin.name = format!("{}-{}", sc.name, layer.sites[site].name);
    twin.specs = keep.iter().map(|&i| sc.specs[i].clone()).collect();
    twin.traces = keep.iter().map(|&i| sc.traces[i].clone()).collect();
    twin.capacity = keep.iter().map(|&i| sc.capacity[i]).collect();
    if !sc.microgrids.is_empty() {
        twin.microgrids = keep.iter().map(|&i| sc.microgrids[i].clone()).collect();
    }
    twin.churn = sc
        .churn
        .iter()
        .filter_map(|ev| {
            pos.get(&ev.node).map(|&p| {
                let mut ev = ev.clone();
                ev.node = p;
                ev
            })
        })
        .collect();
    twin.sites = None;
    twin
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_name_builds() {
        for name in SCENARIO_NAMES {
            let sc = build(name, 0, 0, 7).unwrap_or_else(|| panic!("{name} did not build"));
            assert_eq!(sc.specs.len(), sc.traces.len());
            assert_eq!(sc.specs.len(), sc.capacity.len());
            assert_eq!(sc.requests, 20_000);
            assert_eq!(sc.config.seed, 7);
            assert!(sc.arrivals.mean_rate_hz() > 0.0, "{name}");
        }
        assert!(build("atlantis", 0, 0, 7).is_none());
    }

    #[test]
    fn defaults_match_docs() {
        assert_eq!(build("paper-3-node", 0, 0, 1).unwrap().specs.len(), 3);
        assert_eq!(build("fleet-100", 0, 0, 1).unwrap().specs.len(), 100);
        assert_eq!(build("diurnal-solar", 0, 0, 1).unwrap().specs.len(), 12);
        assert_eq!(build("bursty", 0, 0, 1).unwrap().specs.len(), 3);
        assert_eq!(build("churn", 0, 0, 1).unwrap().specs.len(), 10);
        assert_eq!(build("real-trace", 0, 0, 1).unwrap().specs.len(), 3); // one per zone
        assert_eq!(build("deferral-routing", 0, 0, 1).unwrap().specs.len(), 3);
        assert_eq!(build("consolidation", 0, 0, 1).unwrap().specs.len(), 12);
        assert_eq!(build("solar-battery", 0, 0, 1).unwrap().specs.len(), 4);
        assert_eq!(build("microgrid-fleet", 0, 0, 1).unwrap().specs.len(), 12);
        assert_eq!(build("arbitrage", 0, 0, 1).unwrap().specs.len(), 4);
        assert_eq!(build("batch-serving", 0, 0, 1).unwrap().specs.len(), 4);
        assert_eq!(build("multi-tenant", 0, 0, 1).unwrap().specs.len(), 8);
        assert_eq!(build("multi-site", 0, 0, 1).unwrap().specs.len(), 9);
        assert_eq!(build("follow-the-sun", 0, 0, 1).unwrap().specs.len(), 9);
        // node/request overrides respected
        let sc = build("fleet-100", 25, 500, 1).unwrap();
        assert_eq!(sc.specs.len(), 25);
        assert_eq!(sc.requests, 500);
    }

    #[test]
    fn every_scenario_validates() {
        for name in SCENARIO_NAMES {
            let sc = build(name, 0, 0, 7).unwrap();
            assert!(sc.validate().is_ok(), "{name}: {:?}", sc.validate());
        }
        // Shape violations surface as errors with context.
        let mut sc = build("paper-3-node", 0, 0, 7).unwrap();
        sc.capacity[1] = 0;
        assert!(sc.validate().unwrap_err().contains("capacity"));
        let mut sc = build("paper-3-node", 0, 0, 7).unwrap();
        sc.traces.pop();
        assert!(sc.validate().is_err());
        let mut sc = build("churn", 0, 0, 7).unwrap();
        sc.churn[0].node = 999;
        assert!(sc.validate().unwrap_err().contains("churn"));
        let mut sc = build("real-trace", 0, 0, 7).unwrap();
        sc.config.deferral.as_mut().unwrap().policy.resolution_s = -5.0;
        assert!(sc.validate().unwrap_err().contains("resolution"));
        let mut sc = build("solar-battery", 0, 0, 7).unwrap();
        sc.microgrids[0].as_mut().unwrap().battery.rt_efficiency = 2.0;
        assert!(sc.validate().unwrap_err().contains("microgrid"));
        let mut sc = build("arbitrage", 0, 0, 7).unwrap();
        sc.microgrids[0].as_mut().unwrap().charge =
            ChargePolicy::Threshold { percentile: 5.0, window_s: 86_400.0 };
        assert!(sc.validate().unwrap_err().contains("percentile"));
    }

    #[test]
    fn arbitrage_scenario_shape() {
        let sc = build("arbitrage", 0, 4_000, 7).unwrap();
        assert_eq!(sc.name, "arbitrage");
        assert_eq!(sc.specs.len(), 4);
        assert_eq!(sc.microgrids.len(), 4);
        // Idle-free chassis: every gram is task-attributed.
        for s in &sc.specs {
            assert_eq!(s.idle_w, 0.0);
            assert!((s.rated_power_w - 142.0).abs() < 1e-9);
            // Static intensity mirrors the duck-curve day mean.
            assert!((s.intensity - sc.traces[0].mean(86_400.0, 288)).abs() < 1e-9);
        }
        for mg in sc.microgrids.iter().flatten() {
            assert!(mg.validate().is_ok());
            assert_eq!(mg.battery.capacity_wh, ARBITRAGE_BATTERY_WH);
            assert_eq!(mg.battery.max_discharge_w, ARBITRAGE_DISCHARGE_W);
            assert!(!mg.charge.is_off(), "arbitrage batteries must grid-charge");
            assert_eq!(mg.pv.power_w(43_200.0), 0.0, "no PV: arbitrage isolated");
        }
        // Duck shape: clean night, dirty evening, decline after.
        let tr = &sc.traces[0];
        assert_eq!(tr.at(2.0 * 3_600.0), 140.0);
        assert_eq!(tr.at(18.0 * 3_600.0), 680.0);
        assert_eq!(tr.at(23.0 * 3_600.0), 200.0);
        // ...and it tiles: day 2 repeats day 1.
        assert_eq!(tr.at(86_400.0 + 2.0 * 3_600.0), 140.0);
        // Deferral on with the documented slack; rate pinned regardless of
        // the request count (only the run length changes).
        let d = sc.config.deferral.as_ref().expect("arbitrage defers by default");
        assert_eq!(d.slack_s, ARBITRAGE_SLACK_S);
        assert_eq!(sc.arrivals.mean_rate_hz(), ARBITRAGE_RATE_HZ);
        assert_eq!(build("arbitrage", 0, 20_000, 7).unwrap().arrivals.mean_rate_hz(),
            ARBITRAGE_RATE_HZ);
        assert_eq!(sc.config.base_exec_ms, ARBITRAGE_BASE_EXEC_MS);
        assert!(!sc.config.charge_frozen_forecasts);
        // Twins: charge-off strips only the policy; frozen flips only the
        // forecast mode.
        let off = charge_disabled_twin(&sc);
        assert_eq!(off.name, "arbitrage-no-charge");
        assert!(off.microgrids.iter().flatten().all(|m| m.charge.is_off()));
        assert_eq!(off.requests, sc.requests);
        let frozen = charge_frozen_twin(&sc);
        assert_eq!(frozen.name, "arbitrage-frozen");
        assert!(frozen.config.charge_frozen_forecasts);
        assert!(frozen.microgrids.iter().flatten().all(|m| !m.charge.is_off()));
    }

    #[test]
    fn real_trace_scenario_carries_zone_traces_and_deferral() {
        let sc = build("real-trace", 0, 0, 3).unwrap();
        // Traces are real (piecewise) and mutually distinct zones.
        for tr in &sc.traces {
            assert!(matches!(tr, IntensityTrace::Trace(_)));
        }
        let names: Vec<&str> = sc.specs.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["edge-DE-00", "edge-DK-01", "edge-PL-02"]);
        // Static intensity mirrors the zone's day-mean (cold-start scores).
        for (spec, tr) in sc.specs.iter().zip(&sc.traces) {
            assert!((spec.intensity - tr.mean(86_400.0, 288)).abs() < 1e-9);
        }
        // DK is the clean zone, PL the dirty one.
        assert!(sc.specs[1].intensity < sc.specs[0].intensity);
        assert!(sc.specs[0].intensity < sc.specs[2].intensity);
        // Deferral on by default with the documented 6 h slack.
        let d = sc.config.deferral.as_ref().expect("real-trace defers by default");
        assert_eq!(d.slack_s, REAL_TRACE_SLACK_S);
        // Arrivals span the first half day.
        let rate = sc.arrivals.mean_rate_hz();
        assert!((rate - 20_000.0 / REAL_TRACE_ARRIVAL_WINDOW_S).abs() < 1e-9);
        // Node override cycles zones.
        let big = build("real-trace", 7, 100, 3).unwrap();
        assert_eq!(big.specs.len(), 7);
        assert!(big.specs[3].name.contains("DE"));
        // A broken CSV is a clean error, not a panic.
        assert!(real_trace_from_csv("datetime,zone\n", 0, 0, 1).is_err());
    }

    #[test]
    fn deferral_routing_scenario_shape() {
        let sc = build("deferral-routing", 0, 0, 3).unwrap();
        let rt = build("real-trace", 0, 0, 3).unwrap();
        assert_eq!(sc.name, "deferral-routing");
        // Same zone fleet and deferral contract as real-trace…
        assert_eq!(sc.specs.len(), rt.specs.len());
        for (a, b) in sc.specs.iter().zip(&rt.specs) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.intensity, b.intensity);
        }
        let d = sc.config.deferral.as_ref().expect("deferral on by default");
        assert_eq!(d.slack_s, REAL_TRACE_SLACK_S);
        assert_eq!(sc.arrivals.mean_rate_hz(), rt.arrivals.mean_rate_hz());
        // …but single service slots and ~1 s tasks: the contention regime.
        assert!(sc.capacity.iter().all(|&c| c == 1));
        assert_eq!(sc.config.base_exec_ms, DEFERRAL_ROUTING_BASE_EXEC_MS);
        let service = sc.specs[0].simulate_latency_ms(sc.config.base_exec_ms);
        assert!((900.0..1_200.0).contains(&service), "service {service} ms");
    }

    #[test]
    fn consolidation_scenario_isolates_idle_floors() {
        let small = build("consolidation", 3, 1_000, 7).unwrap();
        let large = build("consolidation", 12, 1_000, 7).unwrap();
        // The workload is identical across fleet sizes…
        assert_eq!(small.arrivals.mean_rate_hz(), large.arrivals.mean_rate_hz());
        assert_eq!(small.requests, large.requests);
        // …and every host is the same idle-capable box on the same grid.
        let (rated, idle) = crate::config::default_host_power().node_power_split();
        for s in small.specs.iter().chain(&large.specs) {
            assert_eq!(s.rated_power_w, rated);
            assert_eq!(s.idle_w, idle);
            assert_eq!(s.intensity, 475.0);
        }
        assert!(idle > 0.3 * rated && idle < 0.5 * rated, "idle {idle} of rated {rated}");
        // The rate keeps ~3 nodes busy: 65% of the 3-node capacity.
        let cap3 = fleet::service_capacity_hz(
            &small.specs[..3],
            &small.capacity[..3],
            small.config.base_exec_ms,
        );
        assert!((small.arrivals.mean_rate_hz() - 0.65 * cap3).abs() < 1e-9);
        // The same-workload contract holds even below the reference size.
        let tiny = build("consolidation", 2, 1_000, 7).unwrap();
        assert_eq!(tiny.arrivals.mean_rate_hz(), large.arrivals.mean_rate_hz());
    }

    #[test]
    fn diurnal_uses_time_varying_traces() {
        let sc = build("diurnal-solar", 0, 0, 1).unwrap();
        for tr in &sc.traces {
            assert!(matches!(tr, IntensityTrace::Diurnal { .. }));
        }
        // Horizon scaling: arrivals spread over the quarter-day window.
        let rate = sc.arrivals.mean_rate_hz();
        assert!((rate - 20_000.0 / DIURNAL_HORIZON_S).abs() < 1e-9);
    }

    #[test]
    fn churn_has_dead_node_and_waves() {
        let sc = build("churn", 9, 0, 3).unwrap();
        assert_eq!(sc.churn[0], ChurnEvent { at_s: 0.0, node: 8, up: false });
        let downs = sc.churn.iter().filter(|e| !e.up).count();
        let ups = sc.churn.iter().filter(|e| e.up).count();
        assert_eq!(downs, 1 + 3); // dead node + n/3 wave
        assert_eq!(ups, 3);
    }

    #[test]
    fn solar_battery_scenario_shape() {
        let sc = build("solar-battery", 0, 0, 7).unwrap();
        assert_eq!(sc.microgrids.len(), sc.specs.len());
        assert!(sc.microgrids.iter().all(Option::is_some));
        for mg in sc.microgrids.iter().flatten() {
            assert!(mg.validate().is_ok());
            assert_eq!(mg.battery.capacity_wh, SOLAR_BATTERY_WH);
            assert_eq!(mg.battery.initial_soc, 0.3);
            // PV window: dark at midnight, peak power at solar noon.
            assert_eq!(mg.pv.power_w(0.0), 0.0);
            assert!((mg.pv.power_w(43_200.0) - SOLAR_BATTERY_PV_PEAK_W).abs() < 1e-9);
        }
        // Identical idle-capable hosts on the same static grid.
        let (rated, idle) = crate::config::default_host_power().node_power_split();
        for s in &sc.specs {
            assert_eq!(s.rated_power_w, rated);
            assert_eq!(s.idle_w, idle);
            assert_eq!(s.intensity, 475.0);
        }
        // Arrivals spread over the full day, independent of fleet size.
        let rate = sc.arrivals.mean_rate_hz();
        assert!((rate - 20_000.0 / SOLAR_BATTERY_HORIZON_S).abs() < 1e-9);
        // The grid-only twin drops every microgrid and nothing else.
        let twin = microgrid_disabled_twin(&sc);
        assert!(twin.microgrids.is_empty());
        assert_eq!(twin.name, "solar-battery-no-mg");
        assert_eq!(twin.requests, sc.requests);
        assert_eq!(twin.config.seed, sc.config.seed);
        assert_eq!(twin.specs.len(), sc.specs.len());
    }

    #[test]
    fn microgrid_fleet_alternates_supply() {
        let sc = build("microgrid-fleet", 0, 500, 5).unwrap();
        assert_eq!(sc.microgrids.len(), 12);
        for (i, mg) in sc.microgrids.iter().enumerate() {
            assert_eq!(mg.is_some(), i % 2 == 0, "node {i}");
            if let Some(mg) = mg {
                assert!(mg.validate().is_ok());
                // Battery sized and charged to carry the node through the run.
                assert!((mg.battery.capacity_wh - 3.0 * sc.specs[i].rated_power_w).abs() < 1e-9);
                assert_eq!(mg.battery.initial_soc, 0.9);
            }
        }
        // Staggered sunrises: node 0 generates right after t = 0, node 8
        // (sunrise 4 h) is still dark then.
        assert!(sc.microgrids[0].as_ref().unwrap().pv.power_w(600.0) > 0.0);
        assert_eq!(sc.microgrids[8].as_ref().unwrap().pv.power_w(600.0), 0.0);
        // Load is well inside the fleet's capacity.
        let cap = fleet::service_capacity_hz(&sc.specs, &sc.capacity, sc.config.base_exec_ms);
        assert!((sc.arrivals.mean_rate_hz() - 0.4 * cap).abs() < 1e-9);
    }

    #[test]
    fn suggest_close_scenario_names() {
        assert_eq!(suggest("solar"), Some("solar-battery"));
        assert_eq!(suggest("paper3node"), Some("paper-3-node"));
        assert_eq!(suggest("brsty"), Some("bursty"));
        assert_eq!(suggest("consolidations"), Some("consolidation"));
        assert_eq!(suggest("microgrid"), Some("microgrid-fleet"));
        assert_eq!(suggest("CHURN"), Some("churn"));
        assert_eq!(suggest("atlantis"), None);
        assert_eq!(suggest(""), None);
        assert_eq!(suggest("x"), None);
        // Exact distances: the helper is a plain Levenshtein.
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("abc", "abc"), 0);
    }

    #[test]
    fn batch_serving_scenario_shape() {
        let sc = build("batch-serving", 0, 1_000, 7).unwrap();
        assert_eq!(sc.name, "batch-serving");
        assert_eq!(sc.specs.len(), 4);
        assert!(sc.capacity.iter().all(|&c| c == 1), "one service slot per node");
        // Batch formation on with the documented window and fill target.
        let spec = sc.config.batching.as_ref().expect("batch-serving batches");
        assert_eq!(spec.window_ms, BATCH_SERVING_WINDOW_MS);
        assert_eq!(spec.max_batch, BATCH_SERVING_MAX_BATCH);
        // One hot model behind three deadline tiers, interactive-heavy.
        let mix = sc.config.workload.as_ref().expect("batch-serving is multi-tenant");
        assert!(mix.validate().is_ok());
        let names: Vec<&str> = mix.classes.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, vec!["interactive", "standard", "background"]);
        // Equal dispatch priority (oldest-head seals first); the tiers
        // differ by SLO budget and traffic share.
        assert!(mix.classes.iter().all(|c| c.priority == 0));
        assert!(mix.classes[0].slo_s < mix.classes[1].slo_s);
        assert!(mix.classes[1].slo_s < mix.classes[2].slo_s);
        assert!(mix.classes[0].weight > mix.classes[2].weight);
        assert!(mix.classes.iter().all(|c| c.exec_scale == 1.0), "one model, many tiers");
        assert_eq!(sc.config.base_exec_ms, BATCH_SERVING_BASE_EXEC_MS);
        // The formation window is a small fraction of one inference.
        let service_ms = sc.specs[0].simulate_latency_ms(BATCH_SERVING_BASE_EXEC_MS);
        assert!(BATCH_SERVING_WINDOW_MS < 0.25 * service_ms);
        // Overloaded for one-per-slot service, absorbable when batched:
        // rate sits between 1× and the fill-8 throughput multiplier.
        let cap_hz = fleet::service_capacity_hz(&sc.specs, &sc.capacity, sc.config.base_exec_ms);
        let rate = sc.arrivals.mean_rate_hz();
        assert!((rate - BATCH_SERVING_OVERLOAD * cap_hz).abs() < 1e-9);
        let batched_gain = 8.0 / 8f64.powf(sc.specs[0].batch_gamma);
        assert!(BATCH_SERVING_OVERLOAD < batched_gain, "batched fleet must keep up");
        // The unbatched twin strips only the batch spec.
        let twin = batching_disabled_twin(&sc);
        assert_eq!(twin.name, "batch-serving-unbatched");
        assert!(twin.config.batching.is_none());
        assert!(twin.config.workload.is_some(), "twin keeps the tenant mix");
        assert_eq!(twin.arrivals.mean_rate_hz(), rate);
        assert_eq!(twin.config.seed, sc.config.seed);
    }

    #[test]
    fn multi_tenant_scenario_shape() {
        let sc = build("multi-tenant", 0, 1_000, 7).unwrap();
        assert_eq!(sc.specs.len(), 8);
        assert!(sc.config.demand_aware_projections);
        assert_eq!(sc.config.batching.as_ref().unwrap().max_batch, 4);
        // Microgrids alternate like microgrid-fleet.
        assert_eq!(sc.microgrids.len(), 8);
        for (i, mg) in sc.microgrids.iter().enumerate() {
            assert_eq!(mg.is_some(), i % 2 == 0, "node {i}");
        }
        // Every class demand fits the smallest REGIONS chassis, and the
        // model-size spread is real (0.5 vs 3.0).
        let mix = sc.config.workload.as_ref().expect("multi-tenant mix");
        assert!(mix.validate().is_ok());
        for (i, c) in mix.classes.iter().enumerate() {
            assert!(c.demand.cpu <= 0.4 && c.demand.mem_mb <= 512, "class {i} must fit");
            assert_eq!(mix.demand_of(i).class, i);
        }
        let scales: Vec<f64> = mix.classes.iter().map(|c| c.exec_scale).collect();
        assert_eq!(scales, vec![0.5, 1.0, 3.0]);
        assert_eq!(mix.classes[2].slo_s, f64::INFINITY, "generate is best-effort");
        // Load inside capacity even at the heavy tenant's scale.
        let cap_hz = fleet::service_capacity_hz(&sc.specs, &sc.capacity, sc.config.base_exec_ms);
        assert!((sc.arrivals.mean_rate_hz() - 0.55 * cap_hz).abs() < 1e-9);
    }

    #[test]
    fn monolithic_baseline_is_single_host() {
        let sc = build("paper-3-node", 0, 0, 5).unwrap();
        let mono = monolithic_of(&sc);
        assert_eq!(mono.specs.len(), 1);
        assert_eq!(mono.specs[0].name, "host-mono");
        assert_eq!(mono.specs[0].intensity, 530.0);
        // ≈142 W full-load host (config::default_host_power calibration)
        assert!((mono.specs[0].rated_power_w - 142.0).abs() < 1e-9);
        assert_eq!(mono.requests, sc.requests);
        assert_eq!(mono.config.seed, sc.config.seed);
    }

    #[test]
    fn multi_site_scenario_shape() {
        let sc = build("multi-site", 0, 1_000, 7).unwrap();
        let layer = sc.sites.as_ref().expect("multi-site has a site layer");
        assert_eq!(layer.sites.len(), 3);
        assert_eq!(layer.site_of.len(), 9);
        // Round-robin partition, region-named nodes, staggered grids.
        for (i, spec) in sc.specs.iter().enumerate() {
            let s = layer.site_of[i];
            assert_eq!(s, i % 3, "node {i}");
            assert!(spec.name.starts_with(MULTI_SITE_REGIONS[s].0), "{}", spec.name);
            assert_eq!(spec.idle_w, 0.0, "geo chassis is idle-free");
            match sc.traces[i] {
                IntensityTrace::Diurnal { mean, amplitude, phase_s, .. } => {
                    assert_eq!(mean, 475.0);
                    assert_eq!(amplitude, MULTI_SITE_GRID_SWING_G);
                    assert_eq!(phase_s, layer.sites[s].tz_offset_s);
                }
                _ => panic!("node {i}: expected a diurnal trace"),
            }
        }
        // The WAN mesh prices every off-diagonal hop identically.
        let link = layer.topology.link(0, 2);
        assert_eq!(link.latency_ms, MULTI_SITE_WAN_LATENCY_MS);
        assert!(link.energy_j > 0.0);
        assert_eq!(layer.topology.link(1, 1).latency_ms, 0.0);
        assert_eq!(layer.router.name(), "deadline");
        assert!(sc.validate().is_ok());
    }

    #[test]
    fn follow_the_sun_scenario_shape() {
        let sc = build("follow-the-sun", 0, 1_000, 7).unwrap();
        let layer = sc.sites.as_ref().expect("follow-the-sun has a site layer");
        // Every node carries a battery-less PV microgrid whose sunrise
        // tracks its region's timezone; the three PV windows tile the day.
        assert_eq!(sc.microgrids.len(), sc.specs.len());
        for (i, mg) in sc.microgrids.iter().enumerate() {
            let mg = mg.as_ref().expect("every follow-the-sun node has PV");
            assert_eq!(mg.battery.capacity_wh, 0.0);
            let tz = layer.sites[layer.site_of[i]].tz_offset_s;
            let noon = 21_600.0 + tz + 21_600.0;
            let peak = FOLLOW_SUN_PV_PEAK_X * sc.specs[i].rated_power_w;
            assert!((mg.pv.power_w(noon) - peak).abs() < 1e-9, "node {i} noon output");
            assert_eq!(mg.pv.power_w(noon + 43_200.0), 0.0, "node {i} night output");
        }
        let d = sc.config.deferral.as_ref().expect("slack makes deadlines finite");
        assert_eq!(d.slack_s, FOLLOW_SUN_SLACK_S);
        assert!(sc.validate().is_ok());
    }

    #[test]
    fn single_site_twin_carves_one_region() {
        let sc = build("follow-the-sun", 0, 1_000, 7).unwrap();
        let rate = sc.arrivals.mean_rate_hz();
        for site in 0..3 {
            let twin = single_site_twin(&sc, site);
            assert_eq!(twin.specs.len(), 3);
            assert_eq!(twin.microgrids.len(), 3);
            assert!(twin.sites.is_none());
            assert!(twin.name.ends_with(MULTI_SITE_REGIONS[site].0), "{}", twin.name);
            // Same planet-wide demand squeezed through one region.
            assert_eq!(twin.arrivals.mean_rate_hz(), rate);
            assert_eq!(twin.requests, sc.requests);
            for spec in &twin.specs {
                assert!(spec.name.starts_with(MULTI_SITE_REGIONS[site].0));
            }
            assert!(twin.validate().is_ok());
        }
    }
}
