//! # CarbonEdge
//!
//! Carbon-aware deep-learning inference framework for sustainable edge
//! computing — a full reproduction of Zhang et al. (CS.DC 2026) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! * **L3 (this crate)** — the coordinator: carbon monitor, carbon-aware
//!   scheduler (Eq. 3–4, Algorithm 1), model partitioner (Eq. 5), deployer,
//!   simulated heterogeneous edge nodes, workload drivers and the experiment
//!   harness that regenerates every table/figure of the paper. Scheduling is
//!   a single joint verdict: [`scheduler::Scheduler::decide`] answers
//!   *where-or-when* ([`scheduler::SchedulingDecision`]: assign / defer /
//!   reject) over a [`scheduler::FleetView`] snapshot carrying per-node
//!   score inputs, queue-delay estimates, blended effective intensities and
//!   short forecasts — [`scheduler::DeferAwareGreenScheduler`] trades node
//!   against time in one decision, while
//!   [`scheduler::RouteThenDefer`] preserves the legacy two-pass shape.
//! * **L3.5** — the [`sim`] discrete-event fleet simulator: the same
//!   schedulers, node models and carbon accounting driven on a *virtual*
//!   clock instead of the real executor. Real execution for fidelity
//!   (golden numerics, paper tables), simulation for scale (thousand-node
//!   fleets, millions of requests, time-varying grids, churn). Its energy
//!   model is two-part — per-node idle floors integrated against the grid
//!   trace plus task-attributed dynamic power — so consolidation effects
//!   are first-class, and arrivals carrying deadline slack can be
//!   *deferred by the scheduler's own verdict* to cleaner forecast slots
//!   (the engine builds per-node forecasts into each [`scheduler::FleetView`]
//!   with [`carbon::DeferralPolicy`]), including against real
//!   ElectricityMaps-style CSV intensity traces
//!   ([`carbon::zone_traces_from_csv`]). Nodes may sit behind a local
//!   [`microgrid`] (PV + battery): draw is covered PV-first, then battery,
//!   then grid, and the *marginal* effective intensity — what the next
//!   task's watts would pay, a function of sunlight, state of charge and
//!   the store's embodied carbon — feeds the schedulers through
//!   `EdgeNode::intensity_override`, so carbon-aware modes follow the sun
//!   and the charge. Batteries may also *arbitrage* the grid
//!   ([`microgrid::ChargePolicy`]): charge during the cleanest fraction
//!   of the day-ahead window, with a stored-carbon ledger pricing every
//!   discharged joule at its embodied intensity; microgrid deferral
//!   forecasts are simulated SoC trajectories
//!   ([`microgrid::Microgrid::project`]), so release slots are priced
//!   against the battery the node will actually have. Service is
//!   *batched and multi-tenant*: a [`workload::WorkloadMix`] tags each
//!   arrival with a [`workload::WorkloadClass`] (its own SLO, model
//!   scale and dispatch priority), [`sim::BatchSpec`] turns each service
//!   slot into a batch-formation queue (seal on fill or window expiry)
//!   whose members share one execution priced by the node's sub-linear
//!   batch latency/power curves ([`node::NodeSpec::batch_latency_ms`],
//!   [`node::NodeSpec::batch_dynamic_power_w`]), schedulers see
//!   per-class queue states through [`scheduler::ClassNodeView`] and can
//!   credit joining a forming batch, and reports break completions, SLO
//!   misses, batch fill and attributed energy/carbon out per class
//!   ([`sim::ClassUsage`]). With batching disabled (window 0, max 1)
//!   the engine is bit-identical to one-task-per-slot serving. Fleets may
//!   further be *geographic* ([`site`]): a [`site::SiteLayer`] partitions
//!   the nodes into regions with their own grids/PV and timezone offsets,
//!   a [`site::SiteTopology`] prices WAN hops (latency + joules per
//!   shipped request, both on the accounting path), and a cross-site
//!   [`site::Router`] — nearest, carbon-greedy, or the deadline-feasible
//!   carbon router — picks which region's grid eats each request before
//!   the local scheduler routes within the site, over O(sites)
//!   [`site::SiteView`] summaries. The `multi-site` and `follow-the-sun`
//!   scenarios show cross-region shifting beating any single-site green
//!   mode once PV peaks rotate across timezones.
//! * **L2** — the JAX model zoo (`python/compile/models.py`), AOT-lowered to
//!   HLO text artifacts consumed by [`runtime`].
//! * **L1** — Pallas kernels (`python/compile/kernels/`) backing every conv
//!   in the zoo.
//!
//! Python never runs on the request path: after `make artifacts` the binary
//! is self-contained — and the [`sim`] layer needs no artifacts at all.
//!
//! # Observability
//!
//! The [`obs`] module is the audit trail behind the aggregates: attach an
//! [`obs::EventSink`] via [`sim::Simulation::try_run_observed`] and the
//! engine streams every arrival, scheduling verdict (with per-candidate
//! scores from [`scheduler::Scheduler::decide_explained`]), dispatch,
//! deferred release, completion, churn transition and microgrid settlement
//! slice as it happens — NDJSON to disk through [`obs::FirehoseSink`] in
//! constant memory, plus an in-process [`obs::Telemetry`] registry whose
//! per-decision overhead histogram is guarded against the paper's 0.03 ms
//! envelope. The firehose is a *verifiable* source of truth: the
//! [`obs::replay`] engine folds an all-filter trace back into a complete
//! [`sim::SimReport`] — counters, energy splits, Eq. 2 carbon,
//! percentiles — purely from events ([`obs::replay::replay_report`]),
//! audits it field by field against a live run
//! ([`obs::replay::verify`], CLI `carbonedge replay --verify`), and
//! diffs two traces in lockstep to the first divergent event
//! ([`obs::replay::diff`], CLI `carbonedge replay --diff`). An
//! [`obs::MonitorSet`] attached via [`sim::Simulation::try_run_monitored`]
//! evaluates in-sim rules over sliding virtual-time windows — carbon
//! burn-rate against a gCO2/s budget, per-class SLO-miss burn, and
//! reject/defer rate — firing alert events into the firehose and leaving
//! per-rule summaries in the report and telemetry (CLI
//! `sim --monitor carbon-budget=G,slo-burn=PCT,window=S`). With no sink
//! or monitors attached nothing is constructed: the default
//! `run`/`try_run` paths are untouched and reports stay bit-identical.
//!
//! # Invariants & lint
//!
//! The guarantees above are equalities over full runs, which runtime
//! tests can only spot-check. The [`analysis`] module (`carbonedge lint`,
//! a first-class CI job) enforces their *preconditions* statically over
//! the source itself:
//!
//! * **Determinism** — D1/D3 forbid `HashMap`/`HashSet` iteration (and
//!   especially f64 folds over it) in simulator modules, because hasher
//!   order varies per process and float addition does not commute: one
//!   unordered fold feeding a [`sim::SimReport`] breaks
//!   traced==untraced and replay==live byte-for-byte equality. D2
//!   forbids wall-clock and ambient-randomness APIs outside the bench
//!   harness — virtual time comes from the event queue, randomness from
//!   seeded [`util::rng`] streams.
//! * **Panic-safety** — P1 flags `unwrap`/`expect` in simulator/metrics
//!   code (a panic poisons a whole fleet sweep), P2 flags release
//!   `assert!`s outside `validate*` one-shots (hot paths re-checking
//!   invariants that validation already guaranteed demote to
//!   `debug_assert!`).
//! * **Unit-hygiene** — U1 flags direct flows between identifiers whose
//!   unit suffixes disagree within a family (`_s`/`_ms`/`_ns`,
//!   `_w`/`_kw`, `_j`/`_wh`/`_kwh`, `_g`/`_kg`); the WAN and battery
//!   ledgers mix all of these.
//!
//! Legitimate exceptions carry `// lint: allow(RULE reason)` waivers
//! naming the invariant that makes them safe; `carbonedge lint --deny
//! rust/src` exits nonzero on anything unwaived, and the
//! `rust/tests/lint.rs` meta-test pins the tree at zero findings.

#![deny(unsafe_code)]

pub mod analysis;
pub mod carbon;
pub mod config;
pub mod coordinator;
pub mod deployer;
pub mod energy;
pub mod experiments;
pub mod metrics;
pub mod microgrid;
pub mod model;
pub mod node;
pub mod obs;
pub mod partitioner;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod site;
pub mod util;
pub mod workload;
