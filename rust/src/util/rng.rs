//! Deterministic PRNG substrate (splitmix64 + xoshiro256**) with the
//! distributions the workload generator and property tests need.
//!
//! `rand` is not in the offline crate set; this is a from-scratch
//! implementation of well-known generators (Blackman & Vigna).

/// xoshiro256** seeded via splitmix64. Deterministic, fast, good quality.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s =
            [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)` (n > 0), via rejection-free Lemire trick.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn seed_stable_exact_integer_and_uniform_values() {
        // Pinned against an independent reference implementation of
        // splitmix64 + xoshiro256**. Generation is pure integer arithmetic
        // (the uniform maps through an exact power-of-two multiply), so
        // these must match bit-for-bit on every platform — the simulator's
        // determinism guarantee rests on it.
        let mut r = Rng::new(42);
        assert_eq!(r.next_u64(), 1546998764402558742);
        assert_eq!(r.next_u64(), 6990951692964543102);
        assert_eq!(r.next_u64(), 12544586762248559009);
        let mut r = Rng::new(7);
        assert_eq!(r.f64(), 0.7005764821796896);
        assert_eq!(r.f64(), 0.2787512294737843);
        assert_eq!(r.f64(), 0.8396274618764198);
    }

    #[test]
    fn seed_stable_exp_and_normal() {
        // exp/normal route through libm (ln, sqrt, cos), which is
        // correctly rounded to within 1 ulp everywhere we build — pin to a
        // tolerance far above 1 ulp but far below any behavioural change.
        let mut r = Rng::new(9);
        for want in [0.0012933912623040553, 0.1448349383570217, 0.07104812619394953] {
            let got = r.exp(2.0);
            assert!((got - want).abs() < 1e-12, "exp: {got} vs {want}");
        }
        let mut r = Rng::new(5);
        for want in [-0.6609817491416791, 0.6293137312379913, 0.25954642531212807] {
            let got = r.normal();
            assert!((got - want).abs() < 1e-9, "normal: {got} vs {want}");
        }
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var {var}");
        assert!(xs.iter().all(|x| (0.0..1.0).contains(x)));
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
