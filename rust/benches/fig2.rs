//! Bench: regenerate paper Fig. 2 (latency vs carbon-efficiency trade-off).

use carbonedge::config::Config;
use carbonedge::coordinator::Coordinator;
use carbonedge::experiments as exp;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let iters: usize =
        std::env::var("CE_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(15);
    let reps: usize = std::env::var("CE_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(1);
    let coord = Coordinator::new(cfg)?;
    let t2 = exp::table2(&coord, "mobilenet_v2", iters, reps)?;
    println!("{}", exp::fig2_render(&t2));
    let green = &t2.reports[4];
    let mono = &t2.reports[0];
    println!(
        "paper Fig. 2 shape: Green 245.8 inf/g vs Mono 189.5 (1.30x); measured {:.1} vs {:.1} ({:.2}x)",
        green.carbon_efficiency,
        mono.carbon_efficiency,
        green.carbon_efficiency / mono.carbon_efficiency
    );
    Ok(())
}
