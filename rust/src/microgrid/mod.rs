//! Local microgrids: PV + battery behind an edge node, making the node's
//! *effective* carbon intensity depend on sunlight and state of charge.
//!
//! The paper prices every joule at the grid's intensity; real edge sites
//! increasingly sit behind local solar and storage (the renewable-
//! availability effect GreenScale shows dominates edge carbon). This
//! module models that supply side:
//!
//! * [`PvProfile`] — photovoltaic generation in watts over virtual time,
//!   backed by the same [`IntensityTrace`] machinery the grid curves use
//!   (`Static`/`Diurnal`/`Trace` variants, CSV ingestion), so the
//!   `at`/`integral` semantics are shared with the carbon accounting path;
//! * [`BatterySpec`] — capacity, charge/discharge rate limits, round-trip
//!   efficiency (applied on the charge side) and initial state of charge;
//! * [`ChargePolicy`] — grid-charge **arbitrage**: `Off` (the default)
//!   charges only from excess PV, `Threshold` additionally imports grid
//!   power into the battery whenever the grid trace sits at or below a
//!   percentile of its own forward window (rate- and headroom-capped);
//! * [`DischargePolicy`] — **opportunity-cost dispatch**: `Greedy` (the
//!   default, the legacy behaviour) spends charge on the first profitable
//!   hour, which on a duck-curve day blows the whole store on the modest
//!   morning ramp and buys grid through the evening peak;
//!   `OpportunityCost` holds discharge until the grid sits at or above a
//!   high percentile of its own forward window — charge is spent on the
//!   *best remaining* hours, not the first acceptable ones;
//! * a **stored-carbon ledger in FIFO tranches** — grid-charged joules
//!   carry their *embodied* intensity (import priced at charge time,
//!   held as one tranche per charge stretch, released oldest-first on
//!   discharge), so arbitrage never launders carbon to zero and a cheap
//!   night-charge discharged first carries *its own* price rather than a
//!   store-average blend: a battery filled at 150 g/kWh discharges at
//!   ≈ 150/η g/kWh, and a tranche dirtier than the current grid simply
//!   holds (discharge walks tranches while `tranche intensity < grid
//!   intensity`; PV-charged joules stay free and always flow). The
//!   ledger balances exactly — `charged == discharged + still stored` —
//!   tranche by tranche;
//! * [`Microgrid`] — the runtime state: over any virtual-time slice, node
//!   draw is covered **PV-first, then battery, then grid**
//!   ([`Microgrid::cover`] / [`Microgrid::settle`]), and excess PV charges
//!   the battery (anything beyond the charger rate or the headroom is
//!   curtailed);
//! * [`Microgrid::project`] — a pure, non-mutating **SoC-trajectory
//!   forecast**: it rolls the same settlement arithmetic forward over a
//!   forecast window (same rate limits, round-trip losses, charge policy
//!   and stored-carbon pricing as the live ledger) and yields
//!   `(t, effective intensity, SoC fraction)` samples on exactly the
//!   [`crate::carbon::DeferralPolicy::forecast`] slot grid — the fix for
//!   the charge-frozen forecasts that deferred work onto batteries that
//!   would be empty by the release slot.
//!
//! Effective-intensity pricing is **marginal**: local supply serves the
//! node's *standing* draw first, and the advertised price is what the
//! *next task's* watts would actually pay ([`NodeDraw`]). The old
//! average-mix blend over the whole draw advertised battery help a
//! rate-capped battery could not deliver to the marginal task;
//! [`Microgrid::frozen_intensity`] preserves that legacy forecast for the
//! A/B twin (`charge_frozen_forecasts`).
//!
//! The fleet simulator ([`crate::sim`]) attaches an optional
//! [`MicrogridSpec`] per node, settles every change of node draw through
//! [`Microgrid::settle`], and pushes [`Microgrid::advertised_intensity`]
//! into `EdgeNode::intensity_override` — so every existing
//! [`crate::scheduler::Scheduler`] transparently follows the sun and the
//! charge without knowing microgrids exist.

use std::collections::VecDeque;

use crate::carbon::{joules_to_kwh, GramsPerKwh, IntensityTrace};

/// Seconds per hour — the Wh ↔ J conversion used throughout.
const WH_TO_J: f64 = 3_600.0;

/// Samples taken over a [`ChargePolicy::Threshold`] window when computing
/// the charge-price percentile.
const THRESHOLD_SAMPLES: usize = 32;

/// Fraction of the threshold window after which a cached threshold is
/// recomputed (the percentile of a day-scale window drifts slowly, so the
/// settlement hot path must not re-sample the trace every slice).
const THRESHOLD_REFRESH_FRAC: f64 = 1.0 / 16.0;

/// Marginal draw assumed when a caller prices a node with no task draw at
/// all (`task_w <= 0`): a meaningful fraction of the node's rated power.
/// One joule of residual charge must not advertise a fully clean node —
/// the battery has to carry this much of the rated draw to move the
/// marginal price (the zero-draw-cliff fix).
pub const MIN_MARGINAL_DRAW_FRAC: f64 = 0.05;

/// Default [`ChargePolicy::Threshold`] percentile: charge from the grid
/// during the cleanest quarter of the forward window.
pub const DEFAULT_CHARGE_PERCENTILE: f64 = 0.25;

/// Default [`ChargePolicy::Threshold`] window: one day of forward trace.
pub const DEFAULT_CHARGE_WINDOW_S: f64 = 86_400.0;

/// Default [`DischargePolicy::OpportunityCost`] percentile: spend charge
/// only during the dirtiest quarter of the forward window.
pub const DEFAULT_DISCHARGE_PERCENTILE: f64 = 0.75;

/// Default [`DischargePolicy::OpportunityCost`] window: one day of
/// forward trace.
pub const DEFAULT_DISCHARGE_WINDOW_S: f64 = 86_400.0;

/// Photovoltaic generation profile: watts as a function of virtual time,
/// reusing [`IntensityTrace`] (value = watts, not gCO₂/kWh).
#[derive(Debug, Clone)]
pub struct PvProfile {
    trace: IntensityTrace,
}

impl PvProfile {
    /// No local generation (0 W at all times).
    pub fn none() -> PvProfile {
        PvProfile { trace: IntensityTrace::Static(0.0) }
    }

    /// Clamped half-sine day curve peaking at `peak_w`: sunrise at 06:00,
    /// solar noon at 12:00, sunset at 18:00, zero overnight (the negative
    /// half of the sinusoid clamps to zero).
    pub fn diurnal(peak_w: f64) -> PvProfile {
        PvProfile::diurnal_with_sunrise(peak_w, 21_600.0)
    }

    /// Like [`PvProfile::diurnal`] with the sunrise moved to `sunrise_s`
    /// (virtual seconds): generation is positive over
    /// `(sunrise, sunrise + 12 h)` of every day. Lets a fleet stagger its
    /// sites across "longitudes".
    pub fn diurnal_with_sunrise(peak_w: f64, sunrise_s: f64) -> PvProfile {
        // lint: allow(P2 one-shot profile-builder guard)
        assert!(peak_w.is_finite() && peak_w >= 0.0, "bad PV peak {peak_w}");
        PvProfile {
            trace: IntensityTrace::Diurnal {
                mean: 0.0,
                amplitude: peak_w,
                period_s: 86_400.0,
                phase_s: sunrise_s,
            },
        }
    }

    /// Generation trace from explicit `(t_seconds, watts)` samples
    /// (step-held, validated and time-sorted).
    pub fn from_samples(points: Vec<(f64, f64)>) -> Result<PvProfile, String> {
        IntensityTrace::from_samples(points).map(|trace| PvProfile { trace })
    }

    /// Generation trace from a single-zone CSV (`timestamp,watts`) — the
    /// same format [`IntensityTrace::from_csv`] accepts for grid curves.
    pub fn from_csv(text: &str) -> Result<PvProfile, String> {
        IntensityTrace::from_csv(text).map(|trace| PvProfile { trace })
    }

    /// Instantaneous generation at `t` (W).
    pub fn power_w(&self, t: f64) -> f64 {
        self.trace.at(t).max(0.0)
    }

    /// Energy generated over `[t0, t1]` (J = W·s), via the trace's exact
    /// piecewise/analytic integral.
    pub fn energy_j(&self, t0: f64, t1: f64) -> f64 {
        self.trace.integral(t0, t1).max(0.0)
    }
}

/// Battery parameters. Rates are independent power limits; the round-trip
/// efficiency is applied entirely on the charge side (storing `x` joules
/// of input yields `rt_efficiency · x` joules of usable charge), which
/// keeps discharge accounting exact.
#[derive(Debug, Clone)]
pub struct BatterySpec {
    pub capacity_wh: f64,
    pub max_charge_w: f64,
    pub max_discharge_w: f64,
    /// Round-trip efficiency in `(0, 1]`.
    pub rt_efficiency: f64,
    /// Initial state of charge as a fraction of capacity, in `[0, 1]`.
    /// The initial charge carries no embodied carbon (it predates the
    /// run's stored-carbon ledger).
    pub initial_soc: f64,
}

impl BatterySpec {
    /// No storage: zero capacity, zero rates.
    pub fn none() -> BatterySpec {
        BatterySpec {
            capacity_wh: 0.0,
            max_charge_w: 0.0,
            max_discharge_w: 0.0,
            rt_efficiency: 1.0,
            initial_soc: 0.0,
        }
    }

    /// A `capacity_wh` battery with 1C symmetric rate limits (a 600 Wh
    /// battery charges/discharges at up to 600 W).
    pub fn simple(capacity_wh: f64, rt_efficiency: f64, initial_soc: f64) -> BatterySpec {
        BatterySpec {
            capacity_wh,
            max_charge_w: capacity_wh,
            max_discharge_w: capacity_wh,
            rt_efficiency,
            initial_soc,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("capacity_wh", self.capacity_wh),
            ("max_charge_w", self.max_charge_w),
            ("max_discharge_w", self.max_discharge_w),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("battery {name} must be finite and >= 0, got {v}"));
            }
        }
        let eff = self.rt_efficiency;
        if !eff.is_finite() || !(eff > 0.0 && eff <= 1.0) {
            return Err(format!("battery rt_efficiency must be in (0, 1], got {eff}"));
        }
        if !self.initial_soc.is_finite() || !(0.0..=1.0).contains(&self.initial_soc) {
            return Err(format!("battery initial_soc must be in [0, 1], got {}", self.initial_soc));
        }
        Ok(())
    }
}

/// When (if ever) the battery may charge **from the grid**.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum ChargePolicy {
    /// Never import grid power into the battery — PV excess only (the
    /// pre-arbitrage behaviour, and the default).
    #[default]
    Off,
    /// Charge from the grid whenever the trace intensity sits at or below
    /// the `percentile` quantile of the trace over `[t, t + window_s]`
    /// (its own forward window), capped by the charger rate and the
    /// efficiency-adjusted headroom. While actively charging, the battery
    /// does not discharge (a single inverter direction).
    Threshold {
        /// Quantile in `(0, 1)`: 0.25 charges during the cleanest quarter
        /// of the window.
        percentile: f64,
        /// Forward window the quantile is computed over (seconds).
        window_s: f64,
    },
}

impl ChargePolicy {
    /// The standard arbitrage policy: charge during the cleanest
    /// `percentile` of the day-ahead window.
    pub fn threshold(percentile: f64) -> ChargePolicy {
        ChargePolicy::Threshold { percentile, window_s: DEFAULT_CHARGE_WINDOW_S }
    }

    pub fn is_off(&self) -> bool {
        matches!(self, ChargePolicy::Off)
    }

    pub fn validate(&self) -> Result<(), String> {
        match self {
            ChargePolicy::Off => Ok(()),
            ChargePolicy::Threshold { percentile, window_s } => {
                if !percentile.is_finite() || !(*percentile > 0.0 && *percentile < 1.0) {
                    return Err(format!(
                        "charge-policy percentile must be in (0, 1), got {percentile}"
                    ));
                }
                if !window_s.is_finite() || *window_s <= 0.0 {
                    return Err(format!("charge-policy window must be > 0, got {window_s}"));
                }
                Ok(())
            }
        }
    }
}

/// When stored charge may be **spent**. The per-tranche profitability
/// gate (a carbon-bearing tranche never discharges into a grid cleaner
/// than its own embodied intensity) applies under either policy; this
/// decides *which* profitable hours are worth the finite charge.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum DischargePolicy {
    /// Spend charge on the first profitable hour (the legacy behaviour).
    /// On a duck-curve day this drains the store into the modest morning
    /// ramp and leaves the evening peak to the grid.
    #[default]
    Greedy,
    /// Hold discharge until the grid sits at or above the `percentile`
    /// quantile of the trace over `[t, t + window_s]` — spend the finite
    /// charge on the best remaining hours of the forward window. A flat
    /// window (no better hour ahead) collapses to greedy.
    OpportunityCost {
        /// Quantile in `(0, 1)`: 0.75 discharges only during the
        /// dirtiest quarter of the window.
        percentile: f64,
        /// Forward window the quantile is computed over (seconds).
        window_s: f64,
    },
}

impl DischargePolicy {
    /// The standard opportunity-cost policy: spend charge during the
    /// dirtiest `1 - percentile` of the day-ahead window.
    pub fn opportunity_cost(percentile: f64) -> DischargePolicy {
        DischargePolicy::OpportunityCost { percentile, window_s: DEFAULT_DISCHARGE_WINDOW_S }
    }

    pub fn is_greedy(&self) -> bool {
        matches!(self, DischargePolicy::Greedy)
    }

    pub fn validate(&self) -> Result<(), String> {
        match self {
            DischargePolicy::Greedy => Ok(()),
            DischargePolicy::OpportunityCost { percentile, window_s } => {
                if !percentile.is_finite() || !(*percentile > 0.0 && *percentile < 1.0) {
                    return Err(format!(
                        "discharge-policy percentile must be in (0, 1), got {percentile}"
                    ));
                }
                if !window_s.is_finite() || *window_s <= 0.0 {
                    return Err(format!("discharge-policy window must be > 0, got {window_s}"));
                }
                Ok(())
            }
        }
    }
}

/// Immutable per-node microgrid configuration a scenario carries; the
/// simulator builds a fresh [`Microgrid`] runtime state from it per run,
/// keeping runs deterministic.
#[derive(Debug, Clone)]
pub struct MicrogridSpec {
    pub pv: PvProfile,
    pub battery: BatterySpec,
    /// Grid-charge arbitrage policy ([`ChargePolicy::Off`] by default).
    pub charge: ChargePolicy,
    /// Stored-charge dispatch policy ([`DischargePolicy::Greedy`] by
    /// default).
    pub discharge: DischargePolicy,
}

impl MicrogridSpec {
    /// Convenience: a diurnal PV array peaking at `pv_peak_w` plus a 1C
    /// battery of `battery_wh` starting at `initial_soc` (no grid charge).
    pub fn solar(
        pv_peak_w: f64,
        battery_wh: f64,
        rt_efficiency: f64,
        initial_soc: f64,
    ) -> MicrogridSpec {
        MicrogridSpec {
            pv: PvProfile::diurnal(pv_peak_w),
            battery: BatterySpec::simple(battery_wh, rt_efficiency, initial_soc),
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        }
    }

    /// Builder: replace the charge policy.
    pub fn with_charge(mut self, charge: ChargePolicy) -> MicrogridSpec {
        self.charge = charge;
        self
    }

    /// Builder: replace the discharge policy.
    pub fn with_discharge(mut self, discharge: DischargePolicy) -> MicrogridSpec {
        self.discharge = discharge;
        self
    }

    pub fn validate(&self) -> Result<(), String> {
        self.battery.validate()?;
        self.charge.validate()?;
        self.discharge.validate()
    }
}

/// How one virtual-time slice of node demand was supplied (all in joules).
/// Invariant: `pv_j + battery_j + grid_j == draw_w · Δt` — the simulator's
/// energy-conservation tests lean on it (`grid_charge_j` is battery input,
/// not node supply, and is tracked separately).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SliceFlow {
    /// PV generation consumed directly by the node.
    pub pv_j: f64,
    /// Battery discharge consumed by the node.
    pub battery_j: f64,
    /// Grid import consumed by the node directly.
    pub grid_j: f64,
    /// Excess PV routed into the battery (input side, before losses).
    pub charged_j: f64,
    /// Excess PV neither consumed nor storable (rate/headroom limits).
    pub curtailed_j: f64,
    /// Grid import routed into the battery (input side, before losses) —
    /// the arbitrage flow ([`ChargePolicy::Threshold`] only).
    pub grid_charge_j: f64,
    /// Embodied carbon bought into the store by this slice's grid charge
    /// (grams at the slice-mean intensity, no PUE — the engine applies
    /// PUE when it moves carbon into its ledgers).
    pub charge_carbon_g: f64,
    /// Embodied carbon released by this slice's battery discharge (grams,
    /// no PUE): each discharged joule priced at its own FIFO tranche's
    /// embodied intensity.
    pub battery_carbon_g: f64,
}

/// The draw profile the marginal effective-intensity price is quoted for:
/// local supply serves `standing_w` (idle floor + tasks already running)
/// first, and the price is what the *next* `task_w` watts would pay.
/// `rated_w` only matters when `task_w <= 0` (the marginal task is then
/// assumed to be [`MIN_MARGINAL_DRAW_FRAC`] of the rated draw).
#[derive(Debug, Clone, Copy)]
pub struct NodeDraw {
    pub standing_w: f64,
    pub task_w: f64,
    pub rated_w: f64,
}

/// One FIFO charge tranche: joules bought into the store in one stretch,
/// carrying the embodied carbon they were priced at when imported (0 for
/// PV-charged and initial joules).
#[derive(Debug, Clone, Copy, PartialEq)]
struct Tranche {
    j: f64,
    carbon_g: f64,
}

/// Stored-energy ledger: joules in the battery plus their embodied
/// carbon, broken into FIFO [`Tranche`]s. `soc_j`/`carbon_g` are the
/// totals (`soc_j == Σ tranche.j`, `carbon_g == Σ tranche.carbon_g`);
/// discharge consumes tranches oldest-first, so a cheap-hour charge
/// discharged first carries *its own* intensity instead of the
/// store-average blend that used to launder a dirty top-up across every
/// stored joule.
#[derive(Debug, Clone)]
struct Store {
    soc_j: f64,
    carbon_g: f64,
    tranches: VecDeque<Tranche>,
}

/// Embodied intensity of one tranche (g/kWh). `carbon_g · 3.6e6 / j` is
/// grams per kWh — the inverse of [`joules_to_kwh`], written as one
/// rounding step so the gating comparisons stay bit-stable.
fn tranche_intensity(t: &Tranche) -> f64 {
    if t.j > 0.0 {
        t.carbon_g * 3.6e6 / t.j
    } else {
        0.0
    }
}

/// Intensity of the *next* joules the store would release (g/kWh): the
/// head (oldest) tranche's embodied intensity, 0 for an empty store. The
/// marginal price, matching FIFO discharge order — not the old
/// store-average.
fn store_intensity(store: &Store) -> f64 {
    match store.tranches.front() {
        Some(t) => tranche_intensity(t),
        None => 0.0,
    }
}

/// Append charged joules to the FIFO. Carbon-free joules merge into a
/// carbon-free tail tranche (PV charges every sunny slice — without the
/// merge the list would grow per slice; with it a PV-only store is always
/// a single tranche and its arithmetic matches the pre-tranche ledger
/// exactly).
fn push_tranche(store: &mut Store, j: f64, carbon_g: f64) {
    if j <= 0.0 {
        return;
    }
    if carbon_g <= 0.0 {
        if let Some(back) = store.tranches.back_mut() {
            if back.carbon_g <= 0.0 {
                back.j += j;
                return;
            }
        }
    }
    store.tranches.push_back(Tranche { j, carbon_g });
}

/// Charge-price threshold at `t` for a [`ChargePolicy::Threshold`]:
/// the configured quantile of `trace` sampled over `[t, t + window]`.
/// When the quantile reaches the window's maximum (a flat window) there
/// is nothing dirtier ahead to arbitrage into, so the threshold collapses
/// to `-inf` (never charge). `cache` holds `(expires_at, threshold)` so
/// the settlement hot path recomputes only every
/// [`THRESHOLD_REFRESH_FRAC`] of the window.
fn charge_threshold(
    policy: &ChargePolicy,
    trace: &IntensityTrace,
    cache: &mut Option<(f64, f64)>,
    t: f64,
) -> Option<f64> {
    let ChargePolicy::Threshold { percentile, window_s } = policy else { return None };
    if let Some((expires, thr)) = cache {
        if t < *expires {
            return Some(*thr);
        }
    }
    let n = THRESHOLD_SAMPLES;
    let mut vals: Vec<f64> =
        (0..n).map(|i| trace.at(t + i as f64 * window_s / (n - 1) as f64)).collect();
    vals.sort_by(f64::total_cmp);
    let thr = vals[(percentile * (n - 1) as f64) as usize];
    let thr = if thr < vals[n - 1] { thr } else { f64::NEG_INFINITY };
    *cache = Some((t + window_s * THRESHOLD_REFRESH_FRAC, thr));
    Some(thr)
}

/// Is the grid-charge policy actively charging at instant `t`?
fn charging_at(
    policy: &ChargePolicy,
    trace: &IntensityTrace,
    cache: &mut Option<(f64, f64)>,
    t: f64,
) -> bool {
    match charge_threshold(policy, trace, cache, t) {
        Some(thr) => trace.at(t) <= thr,
        None => false,
    }
}

/// Discharge floor at `t` for a [`DischargePolicy`]: the configured
/// quantile of `trace` over `[t, t + window]` — discharge is held while
/// the grid sits *below* it (a better hour is still ahead). `Greedy`
/// floors at `-inf` (never hold); so does a flat window (nothing better
/// ahead to wait for). Cached like the charge threshold so the settlement
/// hot path recomputes only every [`THRESHOLD_REFRESH_FRAC`] of the
/// window.
fn discharge_floor(
    policy: &DischargePolicy,
    trace: &IntensityTrace,
    cache: &mut Option<(f64, f64)>,
    t: f64,
) -> f64 {
    let DischargePolicy::OpportunityCost { percentile, window_s } = policy else {
        return f64::NEG_INFINITY;
    };
    if let Some((expires, thr)) = cache {
        if t < *expires {
            return *thr;
        }
    }
    let n = THRESHOLD_SAMPLES;
    let mut vals: Vec<f64> =
        (0..n).map(|i| trace.at(t + i as f64 * window_s / (n - 1) as f64)).collect();
    vals.sort_by(f64::total_cmp);
    let thr = vals[(percentile * (n - 1) as f64) as usize];
    let thr = if thr > vals[0] { thr } else { f64::NEG_INFINITY };
    *cache = Some((t + window_s * THRESHOLD_REFRESH_FRAC, thr));
    thr
}

/// Is the discharge policy holding the store back at instant `t`?
fn holding_at(
    policy: &DischargePolicy,
    trace: &IntensityTrace,
    cache: &mut Option<(f64, f64)>,
    t: f64,
) -> bool {
    trace.at(t) < discharge_floor(policy, trace, cache, t)
}

/// Settle one slice of constant `draw_w` against `spec`, mutating the
/// store (and the threshold cache). The single source of the settlement
/// arithmetic: [`Microgrid::cover`], [`Microgrid::settle`] and
/// [`Microgrid::project`] all flow through here, so the live ledger and
/// the SoC-trajectory forecast can never disagree.
///
/// `grid_mean` is the slice-mean grid intensity used for the discharge
/// gate and to price grid-charged joules; `charging` says whether the
/// policy is importing this slice (which also suppresses discharge);
/// `holding` says whether the [`DischargePolicy`] is keeping the store
/// for a better hour still ahead in its window (greedy: never).
#[allow(clippy::too_many_arguments)]
fn settle_slice(
    spec: &MicrogridSpec,
    store: &mut Store,
    t0: f64,
    t1: f64,
    draw_w: f64,
    grid_mean: f64,
    charging: bool,
    holding: bool,
) -> SliceFlow {
    let dt = t1 - t0;
    debug_assert!(dt >= 0.0, "settle slice reversed: [{t0}, {t1}]");
    if dt <= 0.0 || dt.is_nan() {
        return SliceFlow::default();
    }
    let b = &spec.battery;
    let cap_j = b.capacity_wh * WH_TO_J;
    let demand_j = (draw_w * dt).max(0.0);
    let pv_avail_j = spec.pv.energy_j(t0, t1);
    let pv_j = demand_j.min(pv_avail_j);
    let mut residual_j = demand_j - pv_j;
    // FIFO discharge: consume tranches oldest-first, each gated on its
    // *own* embodied intensity — a carbon-free tranche always discharges
    // (the legacy PV-only behaviour), a carbon-bearing one only when
    // strictly profitable against this slice's grid, and nothing moves
    // while the policy is importing. The walk stops at the first
    // unprofitable tranche, so a free head releases even when a dirty
    // top-up sits behind it.
    let mut battery_j = 0.0;
    let mut battery_carbon_g = 0.0;
    if !charging && !holding {
        let mut want_j = residual_j.min(b.max_discharge_w * dt).max(0.0);
        while want_j > 0.0 {
            let Some(head) = store.tranches.front_mut() else { break };
            if head.carbon_g > 0.0 && tranche_intensity(head) >= grid_mean {
                break;
            }
            let take_j = want_j.min(head.j);
            let released_g = if take_j >= head.j {
                head.carbon_g
            } else {
                head.carbon_g * take_j / head.j
            };
            head.j -= take_j;
            head.carbon_g -= released_g;
            battery_j += take_j;
            battery_carbon_g += released_g;
            want_j -= take_j;
            if head.j <= 0.0 {
                store.tranches.pop_front();
            }
        }
        store.soc_j = (store.soc_j - battery_j).max(0.0);
        store.carbon_g = (store.carbon_g - battery_carbon_g).max(0.0);
    }
    residual_j -= battery_j;
    let grid_j = residual_j.max(0.0);
    // Excess PV charges the battery (free of embodied carbon).
    let excess_j = (pv_avail_j - pv_j).max(0.0);
    let headroom_in_j = (cap_j - store.soc_j).max(0.0) / b.rt_efficiency;
    let charged_j = excess_j.min(b.max_charge_w * dt).min(headroom_in_j);
    let pv_gain_j = (store.soc_j + charged_j * b.rt_efficiency).min(cap_j) - store.soc_j;
    store.soc_j += pv_gain_j;
    push_tranche(store, pv_gain_j, 0.0);
    // Grid-charge arbitrage: whatever charger rate and headroom are left.
    let mut grid_charge_j = 0.0;
    let mut charge_carbon_g = 0.0;
    if charging {
        let rate_left_j = (b.max_charge_w * dt - charged_j).max(0.0);
        let headroom_in_j = (cap_j - store.soc_j).max(0.0) / b.rt_efficiency;
        grid_charge_j = rate_left_j.min(headroom_in_j);
        if grid_charge_j > 0.0 {
            let gain_j = (store.soc_j + grid_charge_j * b.rt_efficiency).min(cap_j) - store.soc_j;
            store.soc_j += gain_j;
            charge_carbon_g = joules_to_kwh(grid_charge_j) * grid_mean;
            store.carbon_g += charge_carbon_g;
            push_tranche(store, gain_j, charge_carbon_g);
        }
    }
    SliceFlow {
        pv_j,
        battery_j,
        grid_j,
        charged_j,
        curtailed_j: excess_j - charged_j,
        grid_charge_j,
        charge_carbon_g,
        battery_carbon_g,
    }
}

/// Marginal effective intensity at instant `t` for a given store state:
/// PV and the (gated, sustainable) battery power serve the standing draw
/// first, and the marginal task pays for whatever is left — battery
/// joules at the head tranche's embodied intensity (what a discharge
/// would actually release next, FIFO), grid joules at `grid_intensity`.
#[allow(clippy::too_many_arguments)]
fn effective_at(
    spec: &MicrogridSpec,
    store: &Store,
    t: f64,
    draw: NodeDraw,
    grid_intensity: GramsPerKwh,
    sustain_s: f64,
    charging: bool,
    holding: bool,
) -> GramsPerKwh {
    debug_assert!(sustain_s > 0.0, "sustain window must be positive");
    let pv_w = spec.pv.power_w(t);
    let s_int = store_intensity(store);
    let available =
        !charging && !holding && (store.carbon_g <= 0.0 || s_int < grid_intensity);
    // The battery may only advertise power its charge can sustain for the
    // advertising window — a near-empty battery must not advertise its
    // full rate and invite a pile-on.
    let batt_w = if available {
        spec.battery.max_discharge_w.min(store.soc_j / sustain_s)
    } else {
        0.0
    };
    let task_w =
        if draw.task_w > 0.0 { draw.task_w } else { MIN_MARGINAL_DRAW_FRAC * draw.rated_w };
    if task_w <= 0.0 || (pv_w <= 0.0 && batt_w <= 0.0) {
        // No marginal demand to price, or no local supply at all: the
        // marginal watt is a grid watt (bit-exactly the raw trace — the
        // shim-equivalence tests rely on it).
        return grid_intensity;
    }
    let standing = draw.standing_w.max(0.0);
    let pv_for_task = (pv_w - standing).max(0.0).min(task_w);
    let standing_residual = (standing - pv_w).max(0.0);
    let batt_for_task = (batt_w - standing_residual).max(0.0).min(task_w - pv_for_task);
    let grid_for_task = (task_w - pv_for_task - batt_for_task).max(0.0);
    (batt_for_task * s_int + grid_for_task * grid_intensity) / task_w
}

/// Runtime microgrid state: spec + stored-energy ledger.
#[derive(Debug, Clone)]
pub struct Microgrid {
    pub spec: MicrogridSpec,
    store: Store,
    /// `(expires_at, threshold)` cache for the charge-price percentile.
    threshold_cache: Option<(f64, f64)>,
    /// `(expires_at, floor)` cache for the discharge-floor percentile.
    discharge_cache: Option<(f64, f64)>,
}

impl Microgrid {
    pub fn new(spec: MicrogridSpec) -> Microgrid {
        if let Err(e) = spec.validate() {
            panic!("invalid microgrid spec: {e}");
        }
        let soc_j = spec.battery.initial_soc * spec.battery.capacity_wh * WH_TO_J;
        let mut store = Store { soc_j, carbon_g: 0.0, tranches: VecDeque::new() };
        // The initial charge predates the ledger: one carbon-free tranche.
        push_tranche(&mut store, soc_j, 0.0);
        Microgrid { spec, store, threshold_cache: None, discharge_cache: None }
    }

    /// State of charge as a fraction of capacity (0 for a zero-capacity
    /// battery).
    pub fn soc_frac(&self) -> f64 {
        let cap_j = self.spec.battery.capacity_wh * WH_TO_J;
        if cap_j > 0.0 {
            self.store.soc_j / cap_j
        } else {
            0.0
        }
    }

    /// Stored energy in Wh.
    pub fn soc_wh(&self) -> f64 {
        self.store.soc_j / WH_TO_J
    }

    /// Embodied carbon of the current store (grams, no PUE): what the
    /// grid-charged share of the charge cost at import time and has not
    /// yet been released by discharge.
    pub fn stored_carbon_g(&self) -> f64 {
        self.store.carbon_g
    }

    /// Embodied intensity of the *next* joules a discharge would release
    /// (g/kWh): the oldest FIFO tranche's price, matching discharge order.
    pub fn stored_intensity(&self) -> GramsPerKwh {
        store_intensity(&self.store)
    }

    /// Cover a constant draw of `draw_w` watts over `[t0, t1]` with no
    /// charge policy in play: PV first, then battery (rate-, charge- and
    /// stored-carbon-gated), then grid; excess PV charges the battery up
    /// to the charger rate and the headroom (efficiency-adjusted), the
    /// rest is curtailed. Returns the supply split; mutates the state of
    /// charge. The policy-free path — the simulator settles through
    /// [`Microgrid::settle`], which adds grid-charge arbitrage on top.
    pub fn cover(&mut self, t0: f64, t1: f64, draw_w: f64) -> SliceFlow {
        // With no grid price in hand the discharge gate is vacuous
        // (infinity) and no trace exists to compute a floor over,
        // reproducing the legacy always-discharge behaviour.
        settle_slice(&self.spec, &mut self.store, t0, t1, draw_w, f64::INFINITY, false, false)
    }

    /// Cover `[t0, t1]` at `draw_w` against the node's grid `trace`,
    /// applying the charge policy: grid-charge when the policy says the
    /// window is cheap (suppressing discharge for that slice), gate
    /// discharge on the store being cleaner than the slice-mean grid, and
    /// price grid-charged joules at the slice-mean intensity into the
    /// stored-carbon ledger.
    pub fn settle(
        &mut self,
        t0: f64,
        t1: f64,
        draw_w: f64,
        trace: &IntensityTrace,
    ) -> SliceFlow {
        let dt = t1 - t0;
        debug_assert!(dt >= 0.0, "settle slice reversed: [{t0}, {t1}]");
        if dt <= 0.0 || dt.is_nan() {
            return SliceFlow::default();
        }
        let charging = charging_at(&self.spec.charge, trace, &mut self.threshold_cache, t0);
        let holding = holding_at(&self.spec.discharge, trace, &mut self.discharge_cache, t0);
        let grid_mean = trace.integral(t0, t1) / dt;
        settle_slice(&self.spec, &mut self.store, t0, t1, draw_w, grid_mean, charging, holding)
    }

    /// Marginal effective carbon intensity (gCO₂/kWh) of handing this
    /// node one more task at instant `t` against a grid currently at
    /// `grid_intensity`: local supply (instantaneous PV, plus the battery
    /// power the charge can sustain for `sustain_s`) serves the standing
    /// draw first, and the marginal `task_w` pays for what is left —
    /// battery joules at the store's embodied intensity, grid joules at
    /// the grid price. Trace-free, so it cannot see the charge policy;
    /// the simulator adverts through [`Microgrid::advertised_intensity`].
    pub fn effective_intensity(
        &self,
        t: f64,
        draw: NodeDraw,
        grid_intensity: GramsPerKwh,
        sustain_s: f64,
    ) -> GramsPerKwh {
        effective_at(&self.spec, &self.store, t, draw, grid_intensity, sustain_s, false, false)
    }

    /// [`Microgrid::effective_intensity`] with the charge and discharge
    /// policies applied: while the policy is importing — or the discharge
    /// floor says a better hour is still ahead — the battery is not
    /// advertised (it will not discharge), so the marginal price is
    /// honest during cheap windows. Mutates only the threshold caches.
    pub fn advertised_intensity(
        &mut self,
        trace: &IntensityTrace,
        t: f64,
        draw: NodeDraw,
        sustain_s: f64,
    ) -> GramsPerKwh {
        let charging = charging_at(&self.spec.charge, trace, &mut self.threshold_cache, t);
        let holding = holding_at(&self.spec.discharge, trace, &mut self.discharge_cache, t);
        effective_at(&self.spec, &self.store, t, draw, trace.at(t), sustain_s, charging, holding)
    }

    /// The legacy (PR-4) charge-frozen forecast sample, kept for the A/B
    /// twin (`SimConfig::charge_frozen_forecasts`): the *average* blend
    /// over the whole draw (standing + task) at the *decision-time* state
    /// of charge, with no charge-policy awareness — exactly the forecast
    /// that defers work onto batteries that will be empty by the release
    /// slot, and advertises battery help a rate-capped battery cannot
    /// give the marginal task.
    pub fn frozen_intensity(
        &self,
        t: f64,
        draw: NodeDraw,
        grid_intensity: GramsPerKwh,
        sustain_s: f64,
    ) -> GramsPerKwh {
        debug_assert!(sustain_s > 0.0, "sustain window must be positive");
        let pv_w = self.spec.pv.power_w(t);
        let batt_w = self.spec.battery.max_discharge_w.min(self.store.soc_j / sustain_s);
        let s_int = store_intensity(&self.store);
        let draw_w = draw.standing_w.max(0.0) + draw.task_w.max(0.0);
        if draw_w <= 0.0 {
            // The legacy marginal view: the first watt is local whenever
            // any local supply exists (the zero-draw cliff).
            return if pv_w > 0.0 || batt_w > 0.0 { 0.0 } else { grid_intensity };
        }
        let pv_used = pv_w.min(draw_w);
        let batt_used = (draw_w - pv_used).min(batt_w).max(0.0);
        let grid_used = (draw_w - pv_used - batt_used).max(0.0);
        (batt_used * s_int + grid_used * grid_intensity) / draw_w
    }

    /// Pure, non-mutating **SoC-trajectory projection**: roll the
    /// settlement forward from `t0` at a constant `draw.standing_w`
    /// (rate limits, round-trip losses, charge policy and stored-carbon
    /// pricing — the same arithmetic as the live ledger) and sample
    /// `(t, marginal effective intensity, SoC fraction)` on exactly the
    /// [`crate::carbon::DeferralPolicy::forecast`] slot grid from `t0` to
    /// `horizon_s`. The first sample equals
    /// [`Microgrid::advertised_intensity`] at `t0`; with no PV and no
    /// battery every sample is bit-equal to the raw grid trace.
    ///
    /// The standing draw is held constant because the engine cannot know
    /// future dispatch — the forecast is *draw*-frozen, no longer
    /// *charge*-frozen.
    pub fn project(
        &self,
        t0: f64,
        horizon_s: f64,
        draw: NodeDraw,
        trace: &IntensityTrace,
        resolution_s: f64,
        sustain_s: f64,
    ) -> Vec<(f64, GramsPerKwh, f64)> {
        debug_assert!(horizon_s >= t0, "projection window reversed");
        debug_assert!(resolution_s > 0.0, "projection resolution must be positive");
        let horizon_s = horizon_s.max(t0);
        let cap_j = self.spec.battery.capacity_wh * WH_TO_J;
        let mut store = self.store.clone();
        let mut cache = self.threshold_cache;
        let mut dcache = self.discharge_cache;
        let mut out =
            Vec::with_capacity(((horizon_s - t0) / resolution_s.max(1e-9)) as usize + 2);
        let mut t = t0;
        loop {
            let charging = charging_at(&self.spec.charge, trace, &mut cache, t);
            let holding = holding_at(&self.spec.discharge, trace, &mut dcache, t);
            let eff = effective_at(
                &self.spec, &store, t, draw, trace.at(t), sustain_s, charging, holding,
            );
            let soc = if cap_j > 0.0 { store.soc_j / cap_j } else { 0.0 };
            out.push((t, eff, soc));
            if t >= horizon_s || resolution_s <= 0.0 {
                break;
            }
            // The slice settles under the same charging/holding verdicts
            // the sample above was priced at (same t, same caches).
            let t_next = (t + resolution_s).min(horizon_s);
            let grid_mean = trace.integral(t, t_next) / (t_next - t);
            settle_slice(
                &self.spec,
                &mut store,
                t,
                t_next,
                draw.standing_w,
                grid_mean,
                charging,
                holding,
            );
            t = t_next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn draw(standing_w: f64, task_w: f64) -> NodeDraw {
        NodeDraw { standing_w, task_w, rated_w: 142.0 }
    }

    #[test]
    fn pv_diurnal_shape() {
        let pv = PvProfile::diurnal(400.0);
        assert_eq!(pv.power_w(0.0), 0.0); // midnight
        assert_eq!(pv.power_w(10_000.0), 0.0); // pre-dawn
        assert!((pv.power_w(43_200.0) - 400.0).abs() < 1e-9); // solar noon
        assert!(pv.power_w(30_000.0) > 0.0 && pv.power_w(30_000.0) < 400.0);
        assert_eq!(pv.power_w(70_000.0), 0.0); // night
        // Daily yield of a clamped half-sine: peak · (2/π) · 12 h.
        let day_j = pv.energy_j(0.0, 86_400.0);
        let want = 400.0 * (2.0 / std::f64::consts::PI) * 43_200.0;
        assert!((day_j - want).abs() / want < 1e-3, "day {day_j} want {want}");
        // Staggered sunrise shifts the window.
        let east = PvProfile::diurnal_with_sunrise(400.0, 0.0);
        assert!(east.power_w(10_000.0) > 0.0);
        assert_eq!(east.power_w(50_000.0), 0.0);
        assert_eq!(PvProfile::none().power_w(43_200.0), 0.0);
        assert_eq!(PvProfile::none().energy_j(0.0, 86_400.0), 0.0);
    }

    #[test]
    fn pv_from_samples_and_csv() {
        let pv = PvProfile::from_samples(vec![(0.0, 0.0), (100.0, 250.0), (200.0, 0.0)]).unwrap();
        assert_eq!(pv.power_w(150.0), 250.0);
        assert!((pv.energy_j(0.0, 300.0) - 250.0 * 100.0).abs() < 1e-9);
        assert!(PvProfile::from_samples(vec![(0.0, -1.0)]).is_err());
        let csv = PvProfile::from_csv("0,0\n100,250\n200,0\n").unwrap();
        assert_eq!(csv.power_w(150.0), 250.0);
        assert!(PvProfile::from_csv("garbage").is_err());
    }

    #[test]
    fn battery_and_policy_validation() {
        assert!(BatterySpec::none().validate().is_ok());
        assert!(BatterySpec::simple(600.0, 0.9, 0.5).validate().is_ok());
        assert!(BatterySpec::simple(-1.0, 0.9, 0.5).validate().is_err());
        assert!(BatterySpec::simple(600.0, 0.0, 0.5).validate().is_err());
        assert!(BatterySpec::simple(600.0, 1.1, 0.5).validate().is_err());
        assert!(BatterySpec::simple(600.0, 0.9, 1.5).validate().is_err());
        assert!(BatterySpec::simple(f64::NAN, 0.9, 0.5).validate().is_err());
        // 1C convention
        let b = BatterySpec::simple(600.0, 0.9, 0.5);
        assert_eq!(b.max_charge_w, 600.0);
        assert_eq!(b.max_discharge_w, 600.0);
        // Charge policies.
        assert!(ChargePolicy::Off.validate().is_ok());
        assert!(ChargePolicy::threshold(0.25).validate().is_ok());
        assert!(ChargePolicy::threshold(0.0).validate().is_err());
        assert!(ChargePolicy::threshold(1.0).validate().is_err());
        assert!(ChargePolicy::Threshold { percentile: 0.25, window_s: 0.0 }
            .validate()
            .is_err());
        assert!(ChargePolicy::default().is_off());
    }

    #[test]
    #[should_panic(expected = "invalid microgrid spec")]
    fn microgrid_rejects_bad_spec() {
        Microgrid::new(MicrogridSpec::solar(100.0, 100.0, 2.0, 0.5));
    }

    #[test]
    fn cover_pv_first_then_battery_then_grid() {
        // Constant 500 W PV, 1000 Wh battery at 50%.
        let mut mg = Microgrid::new(MicrogridSpec {
            pv: PvProfile::from_samples(vec![(0.0, 500.0)]).unwrap(),
            battery: BatterySpec::simple(1_000.0, 1.0, 0.5),
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        });
        // Draw under PV: all PV, battery untouched (and charging from excess).
        let f = mg.cover(0.0, 10.0, 300.0);
        assert!((f.pv_j - 3_000.0).abs() < 1e-9);
        assert_eq!(f.battery_j, 0.0);
        assert_eq!(f.grid_j, 0.0);
        assert!((f.charged_j - 2_000.0).abs() < 1e-9); // 200 W excess × 10 s
        assert!((f.pv_j + f.battery_j + f.grid_j - 3_000.0).abs() < 1e-9);
        // Draw over PV but within battery rate: PV + battery, no grid.
        let f = mg.cover(10.0, 20.0, 900.0);
        assert!((f.pv_j - 5_000.0).abs() < 1e-9);
        assert!((f.battery_j - 4_000.0).abs() < 1e-9);
        assert_eq!(f.grid_j, 0.0);
        // Draw over PV + battery rate (1C = 1000 W): grid takes the rest.
        let f = mg.cover(20.0, 30.0, 2_000.0);
        assert!((f.pv_j - 5_000.0).abs() < 1e-9);
        assert!((f.battery_j - 10_000.0).abs() < 1e-9); // rate-capped
        assert!((f.grid_j - 5_000.0).abs() < 1e-9);
        assert!((f.pv_j + f.battery_j + f.grid_j - 20_000.0).abs() < 1e-9);
        // PV-charged joules stay free of embodied carbon.
        assert_eq!(mg.stored_carbon_g(), 0.0);
        assert_eq!(mg.stored_intensity(), 0.0);
    }

    #[test]
    fn battery_never_exceeds_bounds() {
        let mut mg = Microgrid::new(MicrogridSpec {
            pv: PvProfile::from_samples(vec![(0.0, 1_000.0)]).unwrap(),
            battery: BatterySpec::simple(10.0, 1.0, 0.9), // 10 Wh = 36 kJ
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        });
        // Massive excess: SoC caps at capacity.
        mg.cover(0.0, 3_600.0, 0.0);
        assert!((mg.soc_frac() - 1.0).abs() < 1e-12);
        assert!((mg.soc_wh() - 10.0).abs() < 1e-12);
        // Massive draw with no PV window left: SoC floors at zero, grid
        // absorbs everything beyond the stored energy.
        let mut dark = Microgrid::new(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec::simple(10.0, 1.0, 1.0),
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        });
        let f = dark.cover(0.0, 3_600.0, 100.0); // 360 kJ demand vs 36 kJ stored
        assert!(dark.soc_frac().abs() < 1e-12);
        assert!((f.battery_j - 36_000.0).abs() < 1e-9);
        assert!((f.grid_j - (360_000.0 - 36_000.0)).abs() < 1e-9);
    }

    #[test]
    fn charge_respects_rate_efficiency_and_headroom() {
        // 1000 W of excess PV into a 100 W charger: input rate-capped.
        let mut mg = Microgrid::new(MicrogridSpec {
            pv: PvProfile::from_samples(vec![(0.0, 1_000.0)]).unwrap(),
            battery: BatterySpec {
                capacity_wh: 1_000.0,
                max_charge_w: 100.0,
                max_discharge_w: 100.0,
                rt_efficiency: 0.8,
                initial_soc: 0.0,
            },
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        });
        let f = mg.cover(0.0, 10.0, 0.0);
        assert!((f.charged_j - 1_000.0).abs() < 1e-9); // 100 W × 10 s input
        assert!((f.curtailed_j - 9_000.0).abs() < 1e-9);
        // Only 80% of the input lands as stored charge.
        assert!((mg.soc_wh() - 1_000.0 * 0.8 / 3_600.0).abs() < 1e-12);
        // Near-full battery: charging stops at the headroom, not past it.
        let mut full = Microgrid::new(MicrogridSpec {
            pv: PvProfile::from_samples(vec![(0.0, 1_000.0)]).unwrap(),
            battery: BatterySpec {
                capacity_wh: 1.0, // 3600 J
                max_charge_w: 1_000.0,
                max_discharge_w: 1_000.0,
                rt_efficiency: 0.5,
                initial_soc: 0.5,
            },
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        });
        let f = full.cover(0.0, 100.0, 0.0); // 100 kJ excess vs 1800 J headroom
        assert!((f.charged_j - 1_800.0 / 0.5).abs() < 1e-9); // input = headroom/η
        assert!((full.soc_frac() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cover_conserves_demand_exactly() {
        let mut mg = Microgrid::new(MicrogridSpec::solar(400.0, 600.0, 0.9, 0.3));
        let mut t = 0.0;
        for (dt, dw) in [(500.0, 54.0), (10_000.0, 142.0), (40_000.0, 0.0), (20_000.0, 300.0)] {
            let f = mg.cover(t, t + dt, dw);
            let demand = dw * dt;
            assert!(
                (f.pv_j + f.battery_j + f.grid_j - demand).abs() <= 1e-9 * demand.max(1.0),
                "slice at t={t}: {f:?} vs demand {demand}"
            );
            assert!((0.0..=1.0 + 1e-12).contains(&mg.soc_frac()));
            t += dt;
        }
        // Zero-length slices are exact no-ops.
        let before = mg.soc_frac();
        assert_eq!(mg.cover(t, t, 1_000.0), SliceFlow::default());
        assert_eq!(mg.soc_frac(), before);
    }

    #[test]
    fn grid_charge_buys_embodied_carbon_and_discharge_releases_it() {
        // Clean first hour (100 g), dirty afterwards (800 g): the
        // threshold policy charges during the clean hour and the store
        // carries the import's carbon at ~100/η g/kWh.
        let trace =
            IntensityTrace::from_samples(vec![(0.0, 100.0), (3_600.0, 800.0)]).unwrap();
        let mut mg = Microgrid::new(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec {
                capacity_wh: 100.0,
                max_charge_w: 100.0,
                max_discharge_w: 100.0,
                rt_efficiency: 0.8,
                initial_soc: 0.0,
            },
            charge: ChargePolicy::Threshold { percentile: 0.25, window_s: 7_200.0 },
            discharge: DischargePolicy::Greedy,
        });
        // Hour 1: cheap -> import at the charger rate, no discharge.
        let f = mg.settle(0.0, 3_600.0, 50.0, &trace);
        assert!((f.grid_charge_j - 100.0 * 3_600.0).abs() < 1e-6);
        assert_eq!(f.battery_j, 0.0, "no discharge while importing");
        assert!((f.grid_j - 50.0 * 3_600.0).abs() < 1e-6, "draw served from the grid");
        let want_g = joules_to_kwh(360_000.0) * 100.0; // 0.1 kWh at 100 g
        assert!((f.charge_carbon_g - want_g).abs() < 1e-9);
        assert!((mg.stored_carbon_g() - want_g).abs() < 1e-9);
        // 80 Wh stored carrying 10 g -> 125 g/kWh embodied (= 100/0.8).
        assert!((mg.soc_wh() - 80.0).abs() < 1e-9);
        assert!((mg.stored_intensity() - 125.0).abs() < 1e-6);
        // Hour 2: dirty (800 > 125) -> the store discharges, releasing its
        // embodied carbon pro rata; the ledger balances exactly.
        let f2 = mg.settle(3_600.0, 5_400.0, 100.0, &trace);
        assert!((f2.battery_j - 100.0 * 1_800.0).abs() < 1e-6);
        let released = f2.battery_carbon_g;
        assert!(released > 0.0);
        assert!(
            (released + mg.stored_carbon_g() - want_g).abs() < 1e-9,
            "ledger must balance: {released} + {} vs {want_g}",
            mg.stored_carbon_g()
        );
        // Arbitrage never launders to zero: the released intensity is the
        // stored one (125), not 0 — and far below the dirty grid (800).
        let released_intensity = released * 3.6e6 / f2.battery_j;
        assert!((released_intensity - 125.0).abs() < 1e-6);
    }

    #[test]
    fn dirty_store_holds_until_the_grid_is_dirtier() {
        // Store bought at 500-intensity must not discharge into a 300
        // grid, but must into a 700 one.
        let trace = IntensityTrace::from_samples(vec![
            (0.0, 500.0),
            (3_600.0, 300.0),
            (7_200.0, 700.0),
        ])
        .unwrap();
        let mut mg = Microgrid::new(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec {
                capacity_wh: 100.0,
                max_charge_w: 100.0,
                max_discharge_w: 100.0,
                rt_efficiency: 1.0,
                initial_soc: 0.0,
            },
            // The median of hour 1's forward window lands on 500, so the
            // first hour imports; later windows flatten to 700 and the
            // flat-window guard stops the policy there.
            charge: ChargePolicy::Threshold { percentile: 0.5, window_s: 10_800.0 },
            discharge: DischargePolicy::Greedy,
        });
        let f = mg.settle(0.0, 3_600.0, 50.0, &trace);
        assert!(f.grid_charge_j > 0.0, "first hour should import: {f:?}");
        assert!((mg.stored_intensity() - 500.0).abs() < 1e-6);
        // Hour 2 at 300 < stored 500: the store holds, grid serves.
        let f2 = mg.settle(3_600.0, 7_200.0, 50.0, &trace);
        assert_eq!(f2.battery_j, 0.0, "dirty store must hold: {f2:?}");
        assert_eq!(f2.grid_charge_j, 0.0);
        assert!((f2.grid_j - 50.0 * 3_600.0).abs() < 1e-6);
        // Hour 3 at 700 > stored 500: discharge resumes.
        let f3 = mg.settle(7_200.0, 9_000.0, 50.0, &trace);
        assert!(f3.battery_j > 0.0, "profitable discharge blocked: {f3:?}");
    }

    #[test]
    fn fifo_tranches_release_their_own_intensity_in_order() {
        // Two charge stretches at different prices: hour 1 at 100 g, hour
        // 2 at 200 g (each sits at its forward window's cheap quartile,
        // so the policy imports through both), then a dirty tail forces
        // discharge. FIFO must release tranche 1's carbon first, at
        // tranche 1's price — the old store-average would have blended
        // the two.
        let trace = IntensityTrace::from_samples(vec![
            (0.0, 100.0),
            (3_600.0, 200.0),
            (7_200.0, 800.0),
        ])
        .unwrap();
        let mut mg = Microgrid::new(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec {
                capacity_wh: 300.0,
                max_charge_w: 100.0,
                max_discharge_w: 100.0,
                rt_efficiency: 1.0,
                initial_soc: 0.0,
            },
            charge: ChargePolicy::Threshold { percentile: 0.25, window_s: 10_800.0 },
            discharge: DischargePolicy::Greedy,
        });
        let f1 = mg.settle(0.0, 3_600.0, 0.0, &trace);
        let f2 = mg.settle(3_600.0, 7_200.0, 0.0, &trace);
        assert!(f1.grid_charge_j > 0.0 && f2.grid_charge_j > 0.0, "{f1:?} {f2:?}");
        assert_eq!(mg.store.tranches.len(), 2, "one tranche per charge stretch");
        let (t1_j, t1_g) = (mg.store.tranches[0].j, mg.store.tranches[0].carbon_g);
        let (t2_j, t2_g) = (mg.store.tranches[1].j, mg.store.tranches[1].carbon_g);
        assert!((tranche_intensity(&mg.store.tranches[0]) - 100.0).abs() < 1e-6);
        assert!((tranche_intensity(&mg.store.tranches[1]) - 200.0).abs() < 1e-6);
        // The head price is advertised, not the blend (which would be 150).
        assert!((mg.stored_intensity() - 100.0).abs() < 1e-6);
        // Discharge exactly tranche 1's joules (100 W rate over t1_j/100 s).
        let f3 = mg.settle(7_200.0, 7_200.0 + t1_j / 100.0, 100.0, &trace);
        assert!((f3.battery_j - t1_j).abs() < 1e-6);
        assert!(
            (f3.battery_carbon_g - t1_g).abs() < 1e-9,
            "tranche 1 must release its own carbon: {} vs {t1_g}",
            f3.battery_carbon_g
        );
        // Per-tranche balance: tranche 2 is untouched, the totals balance
        // tranche by tranche.
        assert_eq!(mg.store.tranches.len(), 1);
        assert!((mg.store.tranches[0].j - t2_j).abs() < 1e-6);
        assert!((mg.store.tranches[0].carbon_g - t2_g).abs() < 1e-12);
        assert!((mg.stored_carbon_g() - t2_g).abs() < 1e-9);
        assert!((mg.stored_intensity() - 200.0).abs() < 1e-6);
        let charged = f1.charge_carbon_g + f2.charge_carbon_g;
        assert!((charged - f3.battery_carbon_g - mg.stored_carbon_g()).abs() < 1e-9);
    }

    #[test]
    fn free_head_discharges_past_a_dirty_tail() {
        // Store: [free initial tranche][500 g grid tranche]. At a 300 g
        // grid the free head must flow while the dirty tail holds — the
        // old average gate (250 < 300) would have released *both*,
        // laundering half the tail's price through the blend.
        let trace = IntensityTrace::from_samples(vec![
            (0.0, 500.0),
            (3_600.0, 300.0),
            (7_200.0, 700.0),
        ])
        .unwrap();
        let mut mg = Microgrid::new(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec {
                capacity_wh: 300.0,
                max_charge_w: 100.0,
                max_discharge_w: 100.0,
                rt_efficiency: 1.0,
                initial_soc: 1.0 / 3.0, // 100 Wh free
            },
            // Median of the first forward window sits at 500: hour 1
            // imports on top of the free initial charge.
            charge: ChargePolicy::Threshold { percentile: 0.5, window_s: 10_800.0 },
            discharge: DischargePolicy::Greedy,
        });
        let f1 = mg.settle(0.0, 3_600.0, 0.0, &trace);
        assert!(f1.grid_charge_j > 0.0);
        assert_eq!(mg.store.tranches.len(), 2);
        let free_j = mg.store.tranches[0].j;
        assert_eq!(mg.store.tranches[0].carbon_g, 0.0);
        // Hour 2 at 300 g: demand far beyond the free tranche. Only the
        // free joules flow; the 500 g tranche holds.
        let f2 = mg.settle(3_600.0, 7_200.0, 200.0, &trace);
        assert!((f2.battery_j - free_j).abs() < 1e-6, "{} vs {free_j}", f2.battery_j);
        assert_eq!(f2.battery_carbon_g, 0.0, "free joules release no carbon");
        assert_eq!(mg.store.tranches.len(), 1);
        assert!((mg.stored_intensity() - 500.0).abs() < 1e-6);
        // Past 7200 s at 700 g: the dirty tranche is profitable and flows.
        let f3 = mg.settle(7_200.0, 9_000.0, 200.0, &trace);
        assert!(f3.battery_j > 0.0);
        assert!(f3.battery_carbon_g > 0.0);
    }

    #[test]
    fn pv_charges_merge_into_one_free_tranche() {
        // Many sunny slices must not grow the tranche list: carbon-free
        // charge merges into the free tail, and a PV-only store is always
        // a single tranche (bit-identical arithmetic to the pre-tranche
        // ledger).
        let mut mg = Microgrid::new(MicrogridSpec::solar(400.0, 600.0, 0.9, 0.3));
        let mut t = 30_000.0;
        for _ in 0..40 {
            mg.cover(t, t + 600.0, 54.0);
            t += 600.0;
        }
        assert_eq!(mg.store.tranches.len(), 1, "PV charges must merge");
        assert_eq!(mg.store.tranches[0].carbon_g, 0.0);
        assert!((mg.store.tranches[0].j - mg.store.soc_j).abs() < 1e-9);
    }

    #[test]
    fn effective_intensity_prices_the_marginal_task() {
        const WINDOW: f64 = 60.0;
        // PV 300 W at noon, charged 1C-600 battery, grid at 500 g/kWh.
        let mg = Microgrid::new(MicrogridSpec::solar(300.0, 600.0, 0.9, 1.0));
        let noon = 43_200.0;
        // Standing 100 W, task 88 W: PV covers both -> zero-carbon task.
        assert_eq!(mg.effective_intensity(noon, draw(100.0, 88.0), 500.0, WINDOW), 0.0);
        // Standing 800 W at noon: 300 PV + 600 battery cover standing and
        // leave 100 W for the 200 W task -> half grid.
        let eff = mg.effective_intensity(noon, draw(800.0, 200.0), 500.0, WINDOW);
        assert!((eff - 500.0 * 100.0 / 200.0).abs() < 1e-9, "eff {eff}");
        // Midnight, battery charged: the rate covers standing + task.
        assert_eq!(mg.effective_intensity(0.0, draw(400.0, 142.0), 500.0, WINDOW), 0.0);
        // Depleted battery at midnight: pure grid, bit-exactly.
        let empty = Microgrid::new(MicrogridSpec::solar(300.0, 600.0, 0.9, 0.0));
        assert_eq!(empty.effective_intensity(0.0, draw(54.0, 88.0), 500.0, WINDOW), 500.0);
        // Rate-capped battery: standing eats the rate first — the old
        // average blend advertised (600·0 + 900·500)/1500 to *every* watt;
        // the marginal task at standing 1412 gets none of the battery.
        let eff = mg.effective_intensity(0.0, draw(1_412.0, 88.0), 500.0, WINDOW);
        assert_eq!(eff, 500.0, "rate-capped battery must not discount the marginal task");
    }

    #[test]
    fn one_joule_battery_no_longer_advertises_a_clean_node() {
        // Regression (ISSUE 5 satellite): 1 J of residual charge used to
        // advertise a fully clean node at zero draw and invite a pile-on.
        const WINDOW: f64 = 60.0;
        let tiny = Microgrid::new(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec {
                capacity_wh: 10.0,
                max_charge_w: 500.0,
                max_discharge_w: 500.0,
                rt_efficiency: 1.0,
                initial_soc: 1.0 / 36_000.0, // exactly 1 J
            },
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        });
        // Zero task draw: the marginal watt is priced at 5% of rated
        // (7.1 W), which 1 J sustains for a fraction of a second.
        let eff = tiny.effective_intensity(0.0, draw(0.0, 0.0), 500.0, WINDOW);
        assert!(eff > 0.99 * 500.0, "1 J battery advertised clean: {eff}");
        // The legacy frozen blend shows exactly the old cliff: 0.0.
        assert_eq!(tiny.frozen_intensity(0.0, draw(0.0, 0.0), 500.0, WINDOW), 0.0);
        // A genuinely charged battery still advertises clean.
        let full = Microgrid::new(MicrogridSpec::solar(0.0, 600.0, 1.0, 1.0));
        assert_eq!(full.effective_intensity(0.0, draw(0.0, 0.0), 500.0, WINDOW), 0.0);
        // Sub-threshold PV gets the same treatment: 0.2 W of sun is not a
        // clean node.
        let dim = Microgrid::new(MicrogridSpec {
            pv: PvProfile::from_samples(vec![(0.0, 0.2)]).unwrap(),
            battery: BatterySpec::none(),
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        });
        let eff = dim.effective_intensity(0.0, draw(0.0, 0.0), 500.0, WINDOW);
        assert!(eff > 0.95 * 500.0, "0.2 W of PV advertised clean: {eff}");
    }

    #[test]
    fn effective_intensity_caps_battery_at_sustainable_power() {
        // 1800 J of charge over a 60 s advertising window sustains 30 W —
        // a near-empty battery must not advertise its full 500 W rate.
        let low = Microgrid::new(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec {
                capacity_wh: 10.0, // 36 kJ
                max_charge_w: 500.0,
                max_discharge_w: 500.0,
                rt_efficiency: 1.0,
                initial_soc: 0.05, // 1800 J
            },
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        });
        // Standing 0: the whole 30 W sustainable power serves the task.
        let eff = low.effective_intensity(0.0, draw(0.0, 100.0), 500.0, 60.0);
        assert!((eff - 500.0 * (100.0 - 30.0) / 100.0).abs() < 1e-9, "eff {eff}");
        // A longer window sustains even less; a shorter one more.
        let eff_long = low.effective_intensity(0.0, draw(0.0, 100.0), 500.0, 600.0);
        assert!(eff_long > eff);
        let eff_short = low.effective_intensity(0.0, draw(0.0, 100.0), 500.0, 3.0);
        assert!(eff_short < eff);
        // Fully charged, the rate limit (not the charge) is what binds.
        let full = Microgrid::new(MicrogridSpec::solar(0.0, 10.0, 1.0, 1.0));
        let eff = full.effective_intensity(0.0, draw(0.0, 100.0), 500.0, 60.0);
        // 1C on 10 Wh = 10 W rate, though 36 kJ / 60 s could push 600 W.
        assert!((eff - 500.0 * (100.0 - 10.0) / 100.0).abs() < 1e-9, "eff {eff}");
    }

    #[test]
    fn project_first_sample_matches_advert_and_degenerates_to_trace() {
        let trace =
            IntensityTrace::from_samples(vec![(0.0, 400.0), (600.0, 100.0), (1_200.0, 700.0)])
                .unwrap();
        let d = draw(54.0, 88.0);
        // No PV, no battery: the projection IS the raw trace, bit-equal.
        let bare = Microgrid::new(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec::none(),
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        });
        let proj = bare.project(0.0, 1_500.0, d, &trace, 300.0, 60.0);
        let times: Vec<f64> = proj.iter().map(|&(t, ..)| t).collect();
        assert_eq!(times, vec![0.0, 300.0, 600.0, 900.0, 1_200.0, 1_500.0]);
        for &(t, eff, soc) in &proj {
            assert_eq!(eff, trace.at(t), "bare projection must be the raw trace");
            assert_eq!(soc, 0.0);
        }
        // Charged battery: the first sample equals the advertised price,
        // and the trajectory drains the store (standing 54 W, 72 J).
        let mg = Microgrid::new(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec {
                capacity_wh: 0.02, // 72 J
                max_charge_w: 500.0,
                max_discharge_w: 500.0,
                rt_efficiency: 1.0,
                initial_soc: 1.0,
            },
            charge: ChargePolicy::Off,
            discharge: DischargePolicy::Greedy,
        });
        let proj = mg.project(0.0, 1_500.0, d, &trace, 300.0, 60.0);
        let mut advert = mg.clone();
        assert_eq!(proj[0].1, advert.advertised_intensity(&trace, 0.0, d, 60.0));
        assert_eq!(proj[0].2, 1.0);
        // 72 J at 54 W standing drain dies within the first 300 s slot:
        // later samples see an empty battery — the charge-frozen forecast
        // would have advertised it forever.
        assert_eq!(proj.last().unwrap().2, 0.0, "projection must drain the store");
        assert_eq!(proj.last().unwrap().1, trace.at(1_500.0));
        // Zero-width window: a single sample.
        assert_eq!(mg.project(10.0, 10.0, d, &trace, 300.0, 60.0).len(), 1);
        // project is pure: the live store is untouched.
        assert_eq!(mg.soc_frac(), 1.0);
    }

    #[test]
    fn project_sees_future_grid_charging() {
        // Battery empty now; the trace turns cheap at t = 600 (with dirt
        // ahead at t = 3000, so the flat-window guard stays out of play)
        // and the policy will charge there. The projection's SoC rises —
        // the charge-frozen view would keep the node dirty forever.
        let trace =
            IntensityTrace::from_samples(vec![(0.0, 800.0), (600.0, 100.0), (3_000.0, 800.0)])
                .unwrap();
        let mg = Microgrid::new(MicrogridSpec {
            pv: PvProfile::none(),
            battery: BatterySpec {
                capacity_wh: 100.0,
                max_charge_w: 200.0,
                max_discharge_w: 200.0,
                rt_efficiency: 1.0,
                initial_soc: 0.0,
            },
            charge: ChargePolicy::Threshold { percentile: 0.3, window_s: 3_600.0 },
            discharge: DischargePolicy::Greedy,
        });
        let proj = mg.project(0.0, 3_000.0, draw(54.0, 88.0), &trace, 300.0, 60.0);
        assert_eq!(proj[0].2, 0.0);
        let final_soc = proj.last().unwrap().2;
        assert!(final_soc > 0.0, "projection must see the future charge: {proj:?}");
    }

    #[test]
    fn discharge_policy_validation_and_builder() {
        assert!(DischargePolicy::Greedy.validate().is_ok());
        assert!(DischargePolicy::default().is_greedy());
        assert!(DischargePolicy::opportunity_cost(0.75).validate().is_ok());
        assert!(DischargePolicy::opportunity_cost(0.0).validate().is_err());
        assert!(DischargePolicy::opportunity_cost(1.0).validate().is_err());
        assert!(DischargePolicy::OpportunityCost { percentile: 0.75, window_s: 0.0 }
            .validate()
            .is_err());
        let spec = MicrogridSpec::solar(100.0, 100.0, 1.0, 0.5)
            .with_discharge(DischargePolicy::opportunity_cost(0.75));
        assert!(!spec.discharge.is_greedy());
        assert!(spec.validate().is_ok());
        let bad = MicrogridSpec::solar(100.0, 100.0, 1.0, 0.5)
            .with_discharge(DischargePolicy::opportunity_cost(2.0));
        assert!(bad.validate().is_err());
    }

    /// California-style duck-curve day (gCO₂/kWh per hour): cheap night,
    /// modest morning ramp, clean solar midday, steep evening peak.
    const DUCK_DAY_G: [f64; 24] = [
        150.0, 145.0, 140.0, 140.0, 145.0, 160.0, // night
        380.0, 480.0, 520.0, // morning ramp
        430.0, 330.0, 260.0, 230.0, 225.0, 240.0, 300.0, // solar belly
        520.0, 640.0, 680.0, 660.0, // evening peak
        560.0, 540.0, 300.0, 200.0, // wind-down
    ];

    /// The duck-curve regression the opportunity-cost policy exists for:
    /// a greedy store (every tranche is free, so every hour is
    /// "profitable") spends its whole charge on the cheap night hours and
    /// buys grid through the 680 g evening peak; the opportunity-cost
    /// floor holds the same charge for the dirtiest quarter of the
    /// forward window and lands it on the peak instead.
    #[test]
    fn opportunity_cost_beats_greedy_on_the_duck_curve() {
        // Two tiled days so the forward window always sees a real day.
        let points: Vec<(f64, f64)> = (0..48)
            .map(|h| (h as f64 * 3_600.0, DUCK_DAY_G[h % 24]))
            .collect();
        let trace = IntensityTrace::from_samples(points).unwrap();
        // 200 Wh of free charge, 50 W discharge limit, 50 W constant
        // draw: exactly four hours of coverage to spend on a 24-hour day.
        let battery = BatterySpec {
            capacity_wh: 200.0,
            max_charge_w: 0.0,
            max_discharge_w: 50.0,
            rt_efficiency: 1.0,
            initial_soc: 1.0,
        };
        let mk = |discharge: DischargePolicy| {
            Microgrid::new(MicrogridSpec {
                pv: PvProfile::none(),
                battery: battery.clone(),
                charge: ChargePolicy::Off,
                discharge,
            })
        };
        let run = |mut mg: Microgrid| {
            let mut grid_g = 0.0;
            let mut battery_by_hour = [0.0f64; 24];
            for h in 0..24 {
                let (t0, t1) = (h as f64 * 3_600.0, (h + 1) as f64 * 3_600.0);
                let f = mg.settle(t0, t1, 50.0, &trace);
                let demand = 50.0 * 3_600.0;
                assert!(
                    (f.pv_j + f.battery_j + f.grid_j - demand).abs() < 1e-6,
                    "hour {h} must conserve demand: {f:?}"
                );
                grid_g += joules_to_kwh(f.grid_j) * DUCK_DAY_G[h];
                battery_by_hour[h] = f.battery_j;
            }
            (grid_g, battery_by_hour, mg)
        };
        let (greedy_g, greedy_hours, greedy_mg) = run(mk(DischargePolicy::Greedy));
        let (oc_g, oc_hours, oc_mg) = run(mk(DischargePolicy::opportunity_cost(0.75)));
        // Greedy blows the store on the cheap night: discharge starts at
        // hour 0 and the battery is dry before the morning ramp.
        assert!(greedy_hours[0] > 0.0, "greedy must spend on the first hour");
        assert!(
            greedy_hours[6..].iter().all(|&j| j == 0.0),
            "greedy store must be dry by the ramp: {greedy_hours:?}"
        );
        // Opportunity-cost holds through the cheap night and the solar
        // belly, and spends into the evening peak.
        assert!(
            oc_hours[..6].iter().all(|&j| j == 0.0),
            "opportunity-cost must hold overnight: {oc_hours:?}"
        );
        assert!(
            oc_hours[16..20].iter().any(|&j| j > 0.0),
            "opportunity-cost must spend into the evening peak: {oc_hours:?}"
        );
        // Both spend the full (free) store by end of day.
        assert!(greedy_mg.soc_frac() < 1e-9);
        assert!(oc_mg.soc_frac() < 1e-9, "soc {}", oc_mg.soc_frac());
        // The regression pin: same store, same day, >10% less grid carbon.
        assert!(
            oc_g < 0.9 * greedy_g,
            "opportunity-cost must beat greedy on the duck curve: {oc_g:.1} vs {greedy_g:.1}"
        );
    }

    #[test]
    fn holding_store_is_not_advertised() {
        // Full free battery under an opportunity-cost floor during a
        // cheap hour: the marginal price must be the raw grid — the store
        // is being held for the peak and will not discharge now.
        let points: Vec<(f64, f64)> = (0..48)
            .map(|h| (h as f64 * 3_600.0, DUCK_DAY_G[h % 24]))
            .collect();
        let trace = IntensityTrace::from_samples(points).unwrap();
        let mk = |discharge: DischargePolicy| {
            Microgrid::new(MicrogridSpec {
                pv: PvProfile::none(),
                battery: BatterySpec::simple(600.0, 1.0, 1.0),
                charge: ChargePolicy::Off,
                discharge,
            })
        };
        let d = draw(54.0, 88.0);
        // Hour 2 (140 g, the cheap night): greedy advertises the free
        // store; the opportunity-cost floor holds it back.
        let mut greedy = mk(DischargePolicy::Greedy);
        assert_eq!(greedy.advertised_intensity(&trace, 7_500.0, d, 60.0), 0.0);
        let mut oc = mk(DischargePolicy::opportunity_cost(0.75));
        assert_eq!(oc.advertised_intensity(&trace, 7_500.0, d, 60.0), trace.at(7_500.0));
        // Hour 18 (680 g, the peak): both advertise the store.
        assert_eq!(oc.advertised_intensity(&trace, 65_000.0, d, 60.0), 0.0);
        // The projection sees the hold and the release on the same grid.
        let proj = oc.project(7_500.0, 70_000.0, d, &trace, 3_600.0, 60.0);
        assert_eq!(proj[0].1, trace.at(7_500.0), "held store must not discount slot 0");
        let peak = proj.iter().find(|&&(t, ..)| t >= 61_200.0).unwrap();
        assert!(peak.1 < trace.at(peak.0), "projection must see the peak release: {proj:?}");
    }
}
