//! Per-decision min-max normalized scheduling — the paper's own proposed
//! fix (Sec. V-A) for Balanced mode's limited S_C differentiation:
//! "future work should explore per-decision min-max normalization or
//! constraint-based optimization". Both are implemented here.

use super::{
    score_breakdown_view, FleetView, Scheduler, SchedulingDecision, ScoreBreakdown, TaskDemand,
    Weights,
};

/// NSA variant that min-max normalizes every score component across the
/// feasible set before weighting, so a component's *spread* no longer
/// decides how much influence its weight has.
pub struct NormalizedScheduler {
    pub weights: Weights,
    name: String,
}

impl NormalizedScheduler {
    pub fn new(name: &str, weights: Weights) -> NormalizedScheduler {
        NormalizedScheduler { weights, name: name.to_string() }
    }
}

fn minmax(vals: &[f64]) -> Vec<f64> {
    let lo = vals.iter().cloned().fold(f64::MAX, f64::min);
    let hi = vals.iter().cloned().fold(f64::MIN, f64::max);
    if (hi - lo).abs() < 1e-12 {
        return vec![0.5; vals.len()]; // no differentiation -> neutral
    }
    vals.iter().map(|v| (v - lo) / (hi - lo)).collect()
}

impl Scheduler for NormalizedScheduler {
    fn decide(&mut self, task: &TaskDemand, fleet: &FleetView) -> SchedulingDecision {
        let mut feasible: Vec<(usize, ScoreBreakdown)> = Vec::new();
        for (i, view) in fleet.nodes.iter().enumerate() {
            if !view.feasible(task) {
                continue;
            }
            feasible.push((i, score_breakdown_view(view, task, &self.weights)));
        }
        if feasible.is_empty() {
            return SchedulingDecision::reject();
        }
        if feasible.len() == 1 {
            return SchedulingDecision::Assign(feasible[0].0);
        }
        let col = |f: fn(&ScoreBreakdown) -> f64| -> Vec<f64> {
            feasible.iter().map(|(_, b)| f(b)).collect()
        };
        let (r, l, p, bb, c) = (
            minmax(&col(|b| b.s_r)),
            minmax(&col(|b| b.s_l)),
            minmax(&col(|b| b.s_p)),
            minmax(&col(|b| b.s_b)),
            minmax(&col(|b| b.s_c)),
        );
        let w = &self.weights;
        SchedulingDecision::from_choice(
            feasible
                .iter()
                .enumerate()
                .map(|(k, (i, _))| {
                    (*i, w.r * r[k] + w.l * l[k] + w.p * p[k] + w.b * bb[k] + w.c * c[k])
                })
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i),
        )
    }

    fn name(&self) -> &str {
        &self.name
    }
}

/// Constraint-based variant (the paper's other Sec. V-A suggestion):
/// among nodes whose expected latency is within `latency_slack` of the
/// fastest feasible node, pick the lowest-carbon one.
pub struct ConstrainedGreenScheduler {
    /// Allowed latency multiple over the fastest node (e.g. 1.15 = +15%).
    pub latency_slack: f64,
    name: String,
}

impl ConstrainedGreenScheduler {
    pub fn new(latency_slack: f64) -> ConstrainedGreenScheduler {
        // lint: allow(P2 one-shot constructor guard, pinned by a should_panic test)
        assert!(latency_slack >= 1.0);
        ConstrainedGreenScheduler { latency_slack, name: "constrained-green".into() }
    }
}

impl Scheduler for ConstrainedGreenScheduler {
    fn decide(&mut self, task: &TaskDemand, fleet: &FleetView) -> SchedulingDecision {
        // The view already snapshots each node once: (index, T_avg,
        // current effective intensity) per feasible node.
        let feasible: Vec<(usize, f64, f64)> = fleet
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, view)| {
                if view.feasible(task) {
                    Some((i, view.score_ms(), view.intensity))
                } else {
                    None
                }
            })
            .collect();
        let fastest = feasible.iter().map(|&(_, ms, _)| ms).fold(f64::MAX, f64::min);
        SchedulingDecision::from_choice(
            feasible
                .into_iter()
                .filter(|&(_, ms, _)| ms <= fastest * self.latency_slack)
                .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _, _)| i),
        )
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::NodeRegistry;
    use crate::scheduler::Mode;

    fn pick(s: &mut dyn Scheduler, task: &TaskDemand, r: &NodeRegistry) -> Option<usize> {
        s.decide(task, &FleetView::observe(r.nodes())).assigned()
    }

    #[test]
    fn minmax_normalizes_and_handles_ties() {
        assert_eq!(minmax(&[1.0, 2.0, 3.0]), vec![0.0, 0.5, 1.0]);
        assert_eq!(minmax(&[4.0, 4.0]), vec![0.5, 0.5]);
    }

    #[test]
    fn normalized_balanced_routes_green() {
        // The paper's motivation: with min-max normalization, Balanced
        // (w_C = 0.30) *does* differentiate on carbon and flips to the
        // green node — unlike the raw-score NSA (Table V).
        let r = NodeRegistry::paper_setup();
        let mut s = NormalizedScheduler::new("balanced-norm", Mode::Balanced.weights());
        let i = pick(&mut s, &TaskDemand::default(), &r).unwrap();
        assert_eq!(r.get(i).spec.name, "node-green");
    }

    #[test]
    fn normalized_performance_still_routes_fast() {
        let r = NodeRegistry::paper_setup();
        let mut s = NormalizedScheduler::new("perf-norm", Mode::Performance.weights());
        let i = pick(&mut s, &TaskDemand::default(), &r).unwrap();
        assert_eq!(r.get(i).spec.name, "node-high");
    }

    #[test]
    fn normalized_single_feasible_node() {
        let r = NodeRegistry::paper_setup();
        let task = TaskDemand { mem_mb: 800, ..TaskDemand::default() }; // only node-high
        let mut s = NormalizedScheduler::new("x", Mode::Green.weights());
        assert_eq!(pick(&mut s, &task, &r), Some(0));
        let task = TaskDemand { mem_mb: 4096, ..TaskDemand::default() };
        assert_eq!(pick(&mut s, &task, &r), None);
    }

    #[test]
    fn constrained_green_respects_slack() {
        let r = NodeRegistry::paper_setup();
        // priors: high 250ms, green 625ms. Tight slack -> fastest node.
        let mut tight = ConstrainedGreenScheduler::new(1.05);
        let i = pick(&mut tight, &TaskDemand::default(), &r).unwrap();
        assert_eq!(r.get(i).spec.name, "node-high");
        // Loose slack admits the green node.
        let mut loose = ConstrainedGreenScheduler::new(3.0);
        let i = pick(&mut loose, &TaskDemand::default(), &r).unwrap();
        assert_eq!(r.get(i).spec.name, "node-green");
    }

    #[test]
    #[should_panic]
    fn slack_below_one_rejected() {
        ConstrainedGreenScheduler::new(0.9);
    }
}
